//! Pure-Rust CPU execution of the simulated SMoE transformer family.
//!
//! Implements, operation for operation, the reference semantics of
//! `python/compile/model.py`:
//!
//! * embedding + learned positions, pre-norm residual blocks
//!   (`h += attn(rmsnorm(h))`, `h += moe(rmsnorm(h))`), final RMSNorm and
//!   a weight-tied logits head (`h @ embedᵀ`);
//! * causal multi-head attention with the same `softmax(QKᵀ/√d_h)` scores;
//! * the SMoE FFN block of Eqs. (1)–(3): a linear router, top-k selection
//!   as k rounds of argmax (first index wins ties) with softmax over the
//!   selected logits, **capacity-based dispatch** (queue position per
//!   expert in token-major order, tokens beyond `cfg.capacity` dropped —
//!   identical drop rule to the Pallas dispatch) and SwiGLU experts;
//! * the dense calibration pass of `forward_calib`, producing the exact
//!   8-tuple of statistics tensors the [`crate::calib`] module unpacks.
//!
//! Matrix products go through [`crate::tensor::matmul_blocked_with`], so
//! the forward inherits the [`crate::parallel`] subsystem: outputs are
//! bit-identical at any thread count, and the `*_with(threads)` entry
//! points below give benches explicit serial-vs-parallel control. The
//! [`NativeBackend`] trait impl auto-gates the thread count on the
//! per-call work estimate (same policy as every other hot path).
//!
//! The incremental pair (`run_prefill`/`run_decode`) reuses the exact same
//! building blocks: prefill is the scoring forward with the per-layer K/V
//! projections captured into a [`NativeKvCache`] and the dispatch counts
//! carried over (or, when resuming via `PrefillOpts::resume`, the chunk
//! is appended through the decode-path cache machinery at its absolute
//! positions); decode computes one attention row against the cached K/V
//! and one-token MoE dispatch against the cumulative counts, so every
//! f32 operation (and its order) matches the full forward — which is what
//! makes cached decode logits bit-identical to an uncached re-forward
//! (`rust/tests/generate.rs`). The matmul per-element reduction order is
//! length-independent (ascending-k, see [`crate::tensor::matmul`]), so a
//! 1-row product equals the corresponding row of the batched product.
//!
//! **Batched decode** (`run_decode_batch`) is the continuous-batching hot
//! path: all B active sequences advance one token per call. Weight-side
//! products are shared across the batch — one `[B, d] × [d, ·]` GEMM per
//! attention/router/head projection — and the MoE gathers routed tokens
//! *across sequences* into per-expert row blocks, executing one SwiGLU
//! GEMM per expert per step instead of up to `B · k` vector–matrix
//! products. Attention scores and the capacity queue remain strictly
//! per-sequence (each against its own cache). Because the matmul row
//! reduction is row-independent and every per-sequence accumulation
//! happens in the same order as the single-sequence path, the batch is
//! **bit-identical** per sequence to B separate `run_decode` calls — in
//! fact `run_decode` *is* `run_decode_batch` at B = 1
//! (`rust/tests/decode_batch.rs` pins the equivalence).
//!
//! **Multi-position verify** (`run_verify`) generalizes the batched step
//! to a short ragged run of k_i tokens per sequence — the speculative-
//! decoding scoring primitive. Every position appends its own K/V row
//! before scoring and routes at its own cumulative capacity
//! (`capacity(t0 + i + 1)`, the decode convention), so the logits at
//! every position are bit-identical to k_i sequential `run_decode` calls;
//! `run_decode_batch` is literally the k = 1 wrapper over the same core
//! (`rust/tests/spec_decode.rs` pins the equivalence), and per-position
//! dispatch-count checkpoints feed `rollback_cache` when the caller
//! rejects a draft token.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use anyhow::{anyhow, ensure, Result};

use crate::config::ModelCfg;
use crate::kvpool::{KvPool, PagedSeq, PoolHandle};
use crate::parallel;
use crate::tensor::{dot, gather_rows, matmul_blocked_with, matmul_q8_with, Tensor};
use crate::weights::{QuantTensor, Weights};

use super::{
    downcast_state, Backend, CacheMode, CacheSnapshot, KvCache, ModelState, PrefillOpts,
    RoutingSnapshot, VerifyOut,
};

/// RMSNorm epsilon (mirrors `model.py::rmsnorm`).
const RMS_EPS: f32 = 1e-6;

/// The native CPU backend: executes straight from host weights.
pub struct NativeBackend {
    cfg: ModelCfg,
    /// Expert-parallel shard count: how many workers the routed experts of
    /// each MoE layer are partitioned across in [`moe_execute`]. `1` is
    /// the serial per-expert sweep; any value is bit-identical to it (the
    /// gated combine stays a single expert-ascending queue-order sweep).
    shards: usize,
}

/// Live per-variant routing accumulator: one relaxed atomic counter per
/// `(layer, slot)` plus a routed-token total, bumped by [`moe_execute`]
/// on every **served** dispatch (prefill, chunked prefill, decode,
/// verify — the scoring path `forward_logits_with` deliberately does not
/// record, so offline eval never pollutes the live signal). Relaxed
/// ordering is sound because readers only ever take whole-window
/// snapshots and tolerate tearing across slots — the adaptive loop
/// consumes *frequencies*, not an exact ledger. Deliberately in-memory
/// only: this is live state, not an artifact (see FORMATS.md).
struct RoutingStats {
    /// Flattened `[n_layer, n_slots]` executed-dispatch counters.
    counts: Vec<AtomicU64>,
    /// Token rows routed (counted once, at layer 0).
    tokens: AtomicU64,
    n_slots: usize,
}

impl RoutingStats {
    fn new(n_layer: usize, n_slots: usize) -> Self {
        Self {
            counts: (0..n_layer * n_slots).map(|_| AtomicU64::new(0)).collect(),
            tokens: AtomicU64::new(0),
            n_slots,
        }
    }

    /// Record one executed dispatch set at `layer`: `per_slot[s]` holds
    /// the rows expert-slot `s` actually ran (post-capacity), `tok` the
    /// token rows this forward routed.
    fn record(&self, layer: usize, per_slot: &[Vec<(usize, f32)>], tok: usize) {
        let base = layer * self.n_slots;
        for (slot, assigned) in per_slot.iter().enumerate() {
            if !assigned.is_empty() {
                self.counts[base + slot].fetch_add(assigned.len() as u64, Ordering::Relaxed);
            }
        }
        if layer == 0 {
            self.tokens.fetch_add(tok as u64, Ordering::Relaxed);
        }
    }

    fn snapshot(&self) -> RoutingSnapshot {
        let n_layer = self.counts.len() / self.n_slots.max(1);
        let counts = (0..n_layer)
            .map(|l| {
                (0..self.n_slots)
                    .map(|s| self.counts[l * self.n_slots + s].load(Ordering::Relaxed))
                    .collect()
            })
            .collect();
        RoutingSnapshot { counts, tokens: self.tokens.load(Ordering::Relaxed) }
    }
}

/// Resident native variant: a weight copy plus its physical slot count
/// (and the lazily transposed embedding for the weight-tied decode head),
/// its weight-content hash (folded into every KV fingerprint so hot-swapped
/// variants can never alias prefix blocks) and the live routing
/// accumulator serving traffic writes into.
struct NativeModel {
    weights: Weights,
    n_slots: usize,
    embed_t: OnceLock<Vec<f32>>,
    weights_fp: u64,
    routing: RoutingStats,
}

impl ModelState for NativeModel {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

impl NativeModel {
    /// `embedᵀ` (`[d, vocab]`), built once per resident variant: the
    /// incremental decode head multiplies a single hidden row against it
    /// every step, so re-transposing per call would dominate.
    fn embed_t(&self, cfg: &ModelCfg) -> Result<&[f32]> {
        if let Some(et) = self.embed_t.get() {
            return Ok(et);
        }
        let embed = self.weights.get("embed")?;
        let (d, vocab) = (cfg.d, cfg.vocab);
        ensure!(embed.shape() == [vocab, d], "embed shape mismatch");
        let mut et = vec![0f32; d * vocab];
        for vtok in 0..vocab {
            for j in 0..d {
                et[j * vocab + vtok] = embed.data()[vtok * d + j];
            }
        }
        Ok(self.embed_t.get_or_init(|| et))
    }
}

/// Native per-sequence decode state: per-layer K/V rows plus the
/// cumulative expert-dispatch counts that keep the capacity queue
/// semantics identical to a full token-major forward over the prefix.
struct NativeKvCache {
    /// Tokens cached so far.
    t: usize,
    /// Per layer: cached attention keys, `[t, d]` flattened, growing.
    k: Vec<Vec<f32>>,
    /// Per layer: cached attention values, `[t, d]` flattened, growing.
    v: Vec<Vec<f32>>,
    /// Per layer: cumulative per-slot dispatch counts (the token-major
    /// queue positions of the full forward, carried across steps).
    counts: Vec<Vec<usize>>,
}

impl KvCache for NativeKvCache {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn seq_len(&self) -> usize {
        self.t
    }

    fn byte_size(&self) -> usize {
        let floats: usize = self.k.iter().map(Vec::len).sum::<usize>()
            + self.v.iter().map(Vec::len).sum::<usize>();
        floats * std::mem::size_of::<f32>()
    }

    fn capacity_bytes(&self) -> usize {
        let floats: usize = self.k.iter().map(Vec::capacity).sum::<usize>()
            + self.v.iter().map(Vec::capacity).sum::<usize>();
        floats * std::mem::size_of::<f32>()
    }
}

/// Native **paged** decode state: the sequence's block table in a shared
/// [`KvPool`] plus the same cumulative dispatch counts the flat
/// [`NativeKvCache`] carries. Accepted transparently by `run_decode` /
/// `run_decode_batch`; dropping it releases its blocks (and any unused
/// admission reservation) back to the pool.
struct NativePagedKvCache {
    seq: PagedSeq,
    counts: Vec<Vec<usize>>,
}

impl KvCache for NativePagedKvCache {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn seq_len(&self) -> usize {
        self.seq.seq_len()
    }

    fn byte_size(&self) -> usize {
        self.seq.byte_size()
    }

    fn capacity_bytes(&self) -> usize {
        // allocation granularity is whole blocks; block allocation never
        // copies existing rows, so capacity changes here are not reallocs
        self.seq.byte_size()
    }
}

/// Fork a **paged** cache in O(blocks): the clone shares every block by
/// reference (copy-on-write on the first divergent append) and duplicates
/// only the dispatch counts — the cheap-clone primitive for parallel
/// sampling from one prefilled prompt. Errors when the cache is not a
/// native paged cache (flat caches have no sharable storage).
pub fn fork_paged_cache(cache: &dyn KvCache) -> Result<Box<dyn KvCache>> {
    let pc = cache
        .as_any()
        .downcast_ref::<NativePagedKvCache>()
        .ok_or_else(|| anyhow!("fork requires a paged native kv cache"))?;
    Ok(Box::new(NativePagedKvCache {
        seq: pc.seq.fork(),
        counts: pc.counts.clone(),
    }))
}

/// Mutable view over either native cache flavour — the decode paths are
/// written once against this and stay bit-identical across flavours
/// because only the K/V *storage* differs, never the math or its order.
enum SeqCacheMut<'a> {
    Flat(&'a mut NativeKvCache),
    Paged(&'a mut NativePagedKvCache),
}

impl SeqCacheMut<'_> {
    fn t(&self) -> usize {
        match self {
            SeqCacheMut::Flat(c) => c.t,
            SeqCacheMut::Paged(c) => c.seq.seq_len(),
        }
    }

    fn counts(&self) -> &[Vec<usize>] {
        match self {
            SeqCacheMut::Flat(c) => &c.counts,
            SeqCacheMut::Paged(c) => &c.counts,
        }
    }

    fn counts_mut(&mut self, layer: usize) -> &mut [usize] {
        match self {
            SeqCacheMut::Flat(c) => &mut c.counts[layer],
            SeqCacheMut::Paged(c) => &mut c.counts[layer],
        }
    }
}

/// Downcast a trait-object cache to whichever native flavour it is.
fn seq_cache_mut<'a>(c: &'a mut dyn KvCache, backend: &str) -> Result<SeqCacheMut<'a>> {
    if c.as_any().is::<NativeKvCache>() {
        Ok(SeqCacheMut::Flat(c.as_any_mut().downcast_mut().expect("checked flat")))
    } else if c.as_any().is::<NativePagedKvCache>() {
        Ok(SeqCacheMut::Paged(c.as_any_mut().downcast_mut().expect("checked paged")))
    } else {
        Err(anyhow!("kv cache was not created by the {backend} backend"))
    }
}

/// Sharing-map fingerprint of one executable variant: the router mask, the
/// optional remap table, the physical slot count and whether the expert
/// weights are int8-quantized — everything besides the weights that can
/// change a position's K/V. The quantization flag matters because a
/// quantized variant produces different hidden states (hence different
/// K/V rows) than its f32 source under the *same* mask/remap; without the
/// marker the two could alias shared prefix blocks. `weights_fp` is the
/// resident variant's weight-content hash ([`Weights::content_hash`],
/// computed once at `load_model`): under adaptive serving, a hot-swapped
/// recompressed variant can share a pool with its predecessor at the same
/// mask/remap/slot shape, and only the weight identity separates their
/// K/V rows. Two variants of the same pool never alias blocks unless all
/// five components match.
fn variant_fingerprint(
    mask: &[f32],
    remap: Option<&[i32]>,
    n_slots: usize,
    quantized: bool,
    weights_fp: u64,
) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    n_slots.hash(&mut h);
    quantized.hash(&mut h);
    weights_fp.hash(&mut h);
    for &x in mask {
        x.to_bits().hash(&mut h);
    }
    match remap {
        Some(rm) => {
            1u8.hash(&mut h);
            rm.hash(&mut h);
        }
        None => 0u8.hash(&mut h),
    }
    h.finish()
}

/// Everything one prompt forward produces besides a cache: per-layer K/V
/// rows (`[t, d]` each), cumulative dispatch counts, the last position's
/// logits, and the capacity the dispatch ran at (for the drop-free check
/// gating prefix sharing).
struct PrefillParts {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    counts: Vec<Vec<usize>>,
    logits: Vec<f32>,
    cap: usize,
}

impl NativeBackend {
    /// Bind the backend to one model configuration (serial expert sweep;
    /// see [`NativeBackend::with_expert_shards`]).
    pub fn new(cfg: ModelCfg) -> Self {
        Self { cfg, shards: 1 }
    }

    /// Partition each MoE layer's routed experts across `shards` workers
    /// (expert-parallel sharding). Each expert's gathered SwiGLU block is
    /// independent of every other expert's, so the blocks compute
    /// concurrently; the gated combine stays one sequential
    /// expert-ascending queue-order sweep, so outputs are **bit-identical**
    /// to the `shards = 1` serial path at any value — tests sweep this
    /// directly instead of racing on [`crate::config::env::EXPERT_SHARDS_ENV`].
    /// `0` is clamped to `1`.
    pub fn with_expert_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    /// The configured expert-parallel shard count (>= 1).
    pub fn expert_shards(&self) -> usize {
        self.shards
    }

    /// Worker count for one forward over `tok` tokens: parallel only when
    /// the dominant matmul (the vocab-sized logits head) clears the
    /// [`parallel::PAR_AUTO_WORK`] gate.
    fn auto_threads(&self, tok: usize) -> usize {
        let head = self.cfg.vocab.max(4 * self.cfg.d);
        if tok * self.cfg.d * head >= parallel::PAR_AUTO_WORK {
            parallel::default_threads()
        } else {
            1
        }
    }

    /// The whole-prompt forward shared by both fresh-sequence flavours of
    /// [`Backend::run_prefill`] ([`CacheMode::Flat`] buffers and
    /// [`CacheMode::Paged`] pool blocks): one code path computes the
    /// per-layer K/V rows, dispatch counts and final logits, and the two
    /// storage modes differ only in where the rows are *stored* — which is
    /// what makes flat-vs-paged bit-identity hold by construction
    /// (`rust/tests/kvpool.rs` pins it anyway).
    fn prefill_forward(
        &self,
        m: &NativeModel,
        ids: &[i32],
        mask: &[f32],
        remap: Option<&[i32]>,
    ) -> Result<PrefillParts> {
        let cfg = &self.cfg;
        let t = ids.len();
        ensure!(t >= 1, "prefill needs a non-empty prompt (no position to predict from)");
        ensure!(
            mask.len() == cfg.n_layer * cfg.n_exp,
            "mask must be [{}, {}]",
            cfg.n_layer,
            cfg.n_exp
        );
        if let Some(rm) = remap {
            ensure!(rm.len() == cfg.n_layer * cfg.n_exp, "remap size mismatch");
        }
        let d = cfg.d;
        let w = &m.weights;
        let threads = self.auto_threads(t);
        let cap = cfg.capacity(t, m.n_slots);
        let mut parts = PrefillParts {
            k: Vec::with_capacity(cfg.n_layer),
            v: Vec::with_capacity(cfg.n_layer),
            counts: vec![vec![0usize; m.n_slots]; cfg.n_layer],
            logits: Vec::new(),
            cap,
        };
        let mut h = embed_tokens(cfg, w, ids, t)?;
        for l in 0..cfg.n_layer {
            let ln1 = layer_tensor(w, l, "ln1")?;
            let x1 = rmsnorm_rows(&h, ln1.data(), d);
            let (a, k, v) = attention_seq(cfg, w, l, &x1, t, threads)?;
            parts.k.push(k);
            parts.v.push(v);
            for (hv, av) in h.iter_mut().zip(&a) {
                *hv += av;
            }
            let ln2 = layer_tensor(w, l, "ln2")?;
            let hf = rmsnorm_rows(&h, ln2.data(), d);
            let mask_l = &mask[l * cfg.n_exp..(l + 1) * cfg.n_exp];
            let remap_l = remap.map(|rm| &rm[l * cfg.n_exp..(l + 1) * cfg.n_exp]);
            let y = moe_layer(
                cfg,
                w,
                l,
                &hf,
                t,
                mask_l,
                remap_l,
                m.n_slots,
                threads,
                self.shards,
                &mut parts.counts[l],
                cap,
                Some(&m.routing),
            )?;
            for (hv, yv) in h.iter_mut().zip(&y) {
                *hv += yv;
            }
        }
        let ln_f = w.get("ln_f")?;
        let hn = rmsnorm_rows(&h, ln_f.data(), d);
        let last = &hn[(t - 1) * d..t * d];
        parts.logits = mm(last, m.embed_t(cfg)?, 1, d, cfg.vocab, threads);
        Ok(parts)
    }

    /// [`Backend::run_decode_batch`] with an explicit worker count —
    /// benches and tests can drive controlled thread sweeps through this;
    /// the trait entry point auto-gates on the batch's work estimate
    /// (each individual product is additionally work-gated by `mm`, so
    /// tiny models stay serial either way). Results are bit-identical at
    /// any `threads` (the [`crate::parallel`] determinism contract), and
    /// per sequence bit-identical to a standalone
    /// [`Backend::run_decode`] call.
    pub fn run_decode_batch_with(
        &self,
        state: &dyn ModelState,
        caches: &mut [&mut dyn KvCache],
        tokens: &[i32],
        mask: &[f32],
        remap: Option<&[i32]>,
        threads: usize,
    ) -> Result<Vec<Vec<f32>>> {
        ensure!(
            tokens.len() == caches.len(),
            "decode batch needs one token per cache ({} tokens, {} caches)",
            tokens.len(),
            caches.len()
        );
        // a decode step IS a verify of one-token runs: same shared GEMMs,
        // same per-sequence attention rows and capacity-queue updates in
        // the same order — run_decode_batch is the k = 1 special case of
        // the multi-position core, so decode-vs-verify bit-identity holds
        // by construction instead of by parallel maintenance
        let runs: Vec<&[i32]> = tokens.iter().map(std::slice::from_ref).collect();
        let outs = self.run_verify_batch_with(state, caches, &runs, mask, remap, threads)?;
        Ok(outs
            .into_iter()
            .map(|mut o| o.logits.pop().expect("one logits row per fed token"))
            .collect())
    }

    /// [`Backend::run_verify`] with an explicit worker count: the ragged
    /// multi-position generalization of the batched decode step, feeding
    /// `tokens[s]` (k_s ≥ 1 proposed tokens) to sequence `s` in one
    /// forward.
    ///
    /// Layout: the batch flattens to `sum(k_s)` rows, sequence-major with
    /// positions in order, so every weight-side product is still one
    /// shared GEMM. Attention and the MoE capacity queue remain strictly
    /// per sequence *and per position*: each position appends its own K/V
    /// row before scoring, and routes at its own cumulative capacity
    /// (`capacity(t0 + i + 1)`, the decode convention) against the
    /// sequence's carried counts — operation for operation the i-th of
    /// k_s sequential [`Backend::run_decode`] calls, which is the
    /// bit-identity contract `rust/tests/spec_decode.rs` pins. After each
    /// position's routing the per-layer counts are cloned into that
    /// position's [`CacheSnapshot`], so a speculative caller can
    /// [`Backend::rollback_cache`] to exactly the accepted prefix.
    ///
    /// Everything — geometry, token ids, paged-block feasibility across
    /// the whole batch — is validated before any cache is mutated, so a
    /// bad request cannot leave other sequences half-advanced.
    pub fn run_verify_batch_with(
        &self,
        state: &dyn ModelState,
        caches: &mut [&mut dyn KvCache],
        tokens: &[&[i32]],
        mask: &[f32],
        remap: Option<&[i32]>,
        threads: usize,
    ) -> Result<Vec<VerifyOut>> {
        let m: &NativeModel = downcast_state(state, self.name())?;
        let cfg = &self.cfg;
        let bsz = caches.len();
        ensure!(
            tokens.len() == bsz,
            "verify needs one token run per cache ({} runs, {bsz} caches)",
            tokens.len()
        );
        ensure!(
            mask.len() == cfg.n_layer * cfg.n_exp,
            "mask must be [{}, {}]",
            cfg.n_layer,
            cfg.n_exp
        );
        if let Some(rm) = remap {
            ensure!(rm.len() == cfg.n_layer * cfg.n_exp, "remap size mismatch");
            // the remap table is static, so range-check it in full here —
            // a bad slot must fail before any cache is mutated, not at
            // whatever layer/selection first routes through it
            ensure!(
                rm.iter().all(|&s| s >= 0 && (s as usize) < m.n_slots),
                "remap slot out of range {}",
                m.n_slots
            );
        }
        if bsz == 0 {
            return Ok(Vec::new());
        }
        let mut cs: Vec<SeqCacheMut> = Vec::with_capacity(bsz);
        for c in caches.iter_mut() {
            cs.push(seq_cache_mut(&mut **c, self.name())?);
        }
        let d = cfg.d;
        let hd = d / cfg.heads;
        ensure!(hd * cfg.heads == d, "heads must divide d");
        let w = &m.weights;
        let pos = w.get("pos")?;
        let embed = w.get("embed")?;
        // pre-verify base lengths: paged appends are committed up front
        // below (prepare/commit must interleave to derive slot offsets),
        // so every per-position computation uses these captured bases
        let t0s: Vec<usize> = cs.iter().map(SeqCacheMut::t).collect();
        let rtot: usize = tokens.iter().map(|r| r.len()).sum();
        // validate the whole batch before any cache is mutated, so a bad
        // request cannot leave other sequences half-advanced
        for ((c, run), &t0) in cs.iter().zip(tokens).zip(&t0s) {
            ensure!(!run.is_empty(), "verify runs need at least one token per sequence");
            // a cache prefilled against a different slot layout (e.g. a
            // full-model cache fed to a compact variant) must be rejected
            // here, not mid-layer after attention already appended K/V
            ensure!(
                c.counts().len() == cfg.n_layer
                    && c.counts().iter().all(|ct| ct.len() == m.n_slots),
                "dispatch counts must cover {} slots per layer",
                m.n_slots
            );
            ensure!(
                pos.shape()[0] >= t0 + run.len(),
                "sequence length {} exceeds t_max {}",
                t0 + run.len(),
                pos.shape()[0]
            );
            for &tok in run.iter() {
                ensure!(
                    tok >= 0 && (tok as usize) < cfg.vocab,
                    "token id {tok} out of vocab range {}",
                    cfg.vocab
                );
            }
            match c {
                SeqCacheMut::Flat(fc) => {
                    ensure!(
                        fc.k.len() == cfg.n_layer && fc.v.len() == cfg.n_layer,
                        "kv cache layer count mismatch"
                    );
                    ensure!(
                        fc.k.iter().all(|kb| kb.len() == t0 * d)
                            && fc.v.iter().all(|vb| vb.len() == t0 * d),
                        "kv cache length out of sync"
                    );
                }
                SeqCacheMut::Paged(pc) => {
                    let p = pc.seq.pool().borrow();
                    ensure!(
                        p.n_layer() == cfg.n_layer && p.d() == d,
                        "kv pool geometry (n_layer={}, d={}) does not match the model \
                         (n_layer={}, d={})",
                        p.n_layer(),
                        p.d(),
                        cfg.n_layer,
                        d
                    );
                    ensure!(
                        pc.seq.table().len() == p.blocks_for(t0),
                        "paged kv cache block table out of sync"
                    );
                }
            }
        }
        // block-allocation feasibility for every paged sequence, checked
        // up front so pool exhaustion cannot leave part of the batch
        // half-advanced (allocations below this line cannot fail)
        {
            struct PoolNeed {
                pid: usize,
                handle: PoolHandle,
                res: usize,
                unres: usize,
            }
            let mut needs: Vec<PoolNeed> = Vec::new();
            let need_idx = |needs: &mut Vec<PoolNeed>, pid: usize, handle: &PoolHandle| {
                match needs.iter().position(|n| n.pid == pid) {
                    Some(i) => i,
                    None => {
                        needs.push(PoolNeed {
                            pid,
                            handle: handle.clone(),
                            res: 0,
                            unres: 0,
                        });
                        needs.len() - 1
                    }
                }
            };
            // (pool id, handle, tail block, sharers in this batch) — COW
            // demand is grouped per shared tail: each copy releases one
            // reference, so only min(sharers, refs - 1) sequences actually
            // allocate; the last one left writes in place. Counting one
            // block per sharer would spuriously reject a feasible batch.
            let mut cow_groups: Vec<(usize, PoolHandle, usize, usize)> = Vec::new();
            for ((c, run), &t0) in cs.iter().zip(tokens).zip(&t0s) {
                if let SeqCacheMut::Paged(pc) = c {
                    // planned growth beyond the current table: reserved
                    // first, best-effort overflow for the remainder (a
                    // shared *partial* tail additionally COWs below)
                    let fresh = pc
                        .seq
                        .pool()
                        .blocks_for(t0 + run.len())
                        .saturating_sub(pc.seq.table().len());
                    if fresh > 0 {
                        let i = need_idx(&mut needs, pc.seq.pool().id(), pc.seq.pool());
                        let res = fresh.min(pc.seq.reserved_remaining());
                        needs[i].res += res;
                        needs[i].unres += fresh - res;
                    }
                    if pc.seq.append_block_need() == Some(true) {
                        let pid = pc.seq.pool().id();
                        let tail =
                            *pc.seq.table().last().expect("COW implies a tail block");
                        match cow_groups
                            .iter_mut()
                            .find(|(id, _, b, _)| *id == pid && *b == tail)
                        {
                            Some((.., k)) => *k += 1,
                            None => cow_groups.push((pid, pc.seq.pool().clone(), tail, 1)),
                        }
                    }
                }
            }
            // copy-on-write allocations are always best-effort (extra work
            // a fork forced, not planned growth a reservation was sized for)
            for (pid, handle, tail, sharers) in &cow_groups {
                let refs = handle.borrow().refs(*tail) as usize;
                let i = need_idx(&mut needs, *pid, handle);
                needs[i].unres += (*sharers).min(refs.saturating_sub(1));
            }
            for n in &needs {
                ensure!(
                    n.handle.borrow().can_alloc(n.res, n.unres),
                    "kv pool exhausted: decode step needs {} more blocks than the \
                     budget allows (raise {})",
                    n.res + n.unres,
                    crate::kvpool::KV_BUDGET_ENV
                );
            }
        }
        // slot preparation (one block slot covers every layer's rows for
        // one new token): per sequence, claim and commit every position's
        // slot up front — prepare derives the local offset from the
        // committed length, so the pair must interleave — with fresh
        // blocks or copy-on-write where needed. The feasibility check
        // above means this cannot fail.
        let mut slots: Vec<Vec<(usize, usize)>> = Vec::with_capacity(bsz);
        for (c, run) in cs.iter_mut().zip(tokens) {
            slots.push(match c {
                SeqCacheMut::Flat(_) => Vec::new(),
                SeqCacheMut::Paged(pc) => {
                    let mut claimed = Vec::with_capacity(run.len());
                    for _ in 0..run.len() {
                        let slot = pc.seq.prepare_append()?;
                        claimed.push(slot);
                        pc.seq.commit_append();
                    }
                    claimed
                }
            });
        }
        // embedding + learned positions: each row at its own absolute
        // position t0 + i within its sequence
        let mut h = vec![0f32; rtot * d];
        let mut r0 = 0usize;
        for (run, &t0) in tokens.iter().zip(&t0s) {
            for (i, &tok) in run.iter().enumerate() {
                let e = &embed.data()[(tok as usize) * d..(tok as usize) * d + d];
                let p = &pos.data()[(t0 + i) * d..(t0 + i + 1) * d];
                for j in 0..d {
                    h[(r0 + i) * d + j] = e[j] + p[j];
                }
            }
            r0 += run.len();
        }
        // per-position dispatch-count checkpoints, filled layer by layer
        // during routing: ckpts[s][i] grows to [n_layer][n_slots]
        let mut ckpts: Vec<Vec<Vec<Vec<usize>>>> = tokens
            .iter()
            .map(|run| vec![Vec::with_capacity(cfg.n_layer); run.len()])
            .collect();
        let mut row = Vec::new();
        for l in 0..cfg.n_layer {
            let ln1 = layer_tensor(w, l, "ln1")?;
            let x1 = rmsnorm_rows(&h, ln1.data(), d);
            let wq = layer_tensor(w, l, "attn.wq")?;
            let wk = layer_tensor(w, l, "attn.wk")?;
            let wv = layer_tensor(w, l, "attn.wv")?;
            let wo = layer_tensor(w, l, "attn.wo")?;
            // projection weights shared across the whole flattened batch:
            // one [R, d] x [d, d] GEMM each (row-identical to R
            // single-row products)
            let q = mm(&x1, wq.data(), rtot, d, d, threads);
            let knew = mm(&x1, wk.data(), rtot, d, d, threads);
            let vnew = mm(&x1, wv.data(), rtot, d, d, threads);
            // scores stay per-sequence per-position, each against its own
            // cached K/V with its own row appended first — the causal
            // accumulation of sequential decode, position by position
            let mut ctx = vec![0f32; rtot * d];
            let mut r0 = 0usize;
            for (s, c) in cs.iter_mut().enumerate() {
                let t0 = t0s[s];
                for i in 0..tokens[s].len() {
                    let r = r0 + i;
                    let kr = &knew[r * d..(r + 1) * d];
                    let vr = &vnew[r * d..(r + 1) * d];
                    match c {
                        SeqCacheMut::Flat(fc) => {
                            fc.k[l].extend_from_slice(kr);
                            fc.v[l].extend_from_slice(vr);
                            ensure!(
                                fc.k[l].len() == (t0 + i + 1) * d,
                                "kv cache length out of sync"
                            );
                            attention_row_cached(
                                cfg,
                                &q[r * d..(r + 1) * d],
                                &fc.k[l],
                                &fc.v[l],
                                t0 + i,
                                &mut ctx[r * d..(r + 1) * d],
                                &mut row,
                            );
                        }
                        SeqCacheMut::Paged(pc) => {
                            let (blk, local) = slots[s][i];
                            {
                                let mut p = pc.seq.pool().borrow_mut();
                                p.write_k(blk, l, local, kr);
                                p.write_v(blk, l, local, vr);
                            }
                            let p = pc.seq.pool().borrow();
                            attention_row_paged(
                                cfg,
                                &q[r * d..(r + 1) * d],
                                &p,
                                pc.seq.table(),
                                l,
                                t0 + i,
                                &mut ctx[r * d..(r + 1) * d],
                                &mut row,
                            );
                        }
                    }
                }
                r0 += tokens[s].len();
            }
            let a = mm(&ctx, wo.data(), rtot, d, d, threads);
            for (hv, av) in h.iter_mut().zip(&a) {
                *hv += av;
            }
            let ln2 = layer_tensor(w, l, "ln2")?;
            let hf = rmsnorm_rows(&h, ln2.data(), d);
            let mask_l = &mask[l * cfg.n_exp..(l + 1) * cfg.n_exp];
            let remap_l = remap.map(|rm| &rm[l * cfg.n_exp..(l + 1) * cfg.n_exp]);
            let y = moe_verify(
                cfg, w, l, &hf, tokens, &t0s, mask_l, remap_l, m.n_slots, threads,
                self.shards, &mut cs, &mut ckpts, Some(&m.routing),
            )?;
            for (hv, yv) in h.iter_mut().zip(&y) {
                *hv += yv;
            }
        }
        let ln_f = w.get("ln_f")?;
        let hn = rmsnorm_rows(&h, ln_f.data(), d);
        let logits = mm(&hn, m.embed_t(cfg)?, rtot, d, cfg.vocab, threads);
        for (c, run) in cs.iter_mut().zip(tokens) {
            if let SeqCacheMut::Flat(fc) = c {
                fc.t += run.len(); // paged lengths were committed per claimed slot
            }
        }
        let mut outs = Vec::with_capacity(bsz);
        let mut r0 = 0usize;
        for ((run, &t0), counts) in tokens.iter().zip(&t0s).zip(ckpts) {
            let rows = logits[r0 * cfg.vocab..(r0 + run.len()) * cfg.vocab]
                .chunks(cfg.vocab)
                .map(<[f32]>::to_vec)
                .collect();
            let checkpoints = counts
                .into_iter()
                .enumerate()
                .map(|(i, c)| CacheSnapshot::new(t0 + i + 1, c))
                .collect();
            outs.push(VerifyOut { logits: rows, checkpoints });
            r0 += run.len();
        }
        Ok(outs)
    }

    /// The resume arm of [`Backend::run_prefill`]: run the next `c`
    /// prompt tokens of a chunked prefill through the layer stack and
    /// append their K/V rows to `existing` (flat or paged — the same
    /// cache-append machinery the decode path uses, generalised from one
    /// token to a block of `c`). Every accumulation happens at the
    /// token's *absolute* position `t0 + i`, so the chunk's rows are
    /// bit-identical to the same positions of a whole-prompt forward;
    /// MoE capacity follows the decode convention (each token's own
    /// cumulative length), which coincides with the whole-prompt rule on
    /// drop-free token sets — the proviso on the trait contract.
    ///
    /// Like the batched decode path, everything is validated — including
    /// paged block feasibility — before any cache mutation, so a failed
    /// chunk leaves the sequence exactly where it was.
    fn prefill_append(
        &self,
        m: &NativeModel,
        ids: &[i32],
        mask: &[f32],
        remap: Option<&[i32]>,
        existing: &mut dyn KvCache,
    ) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let c = ids.len();
        ensure!(c >= 1, "prefill chunk needs at least one token");
        ensure!(
            mask.len() == cfg.n_layer * cfg.n_exp,
            "mask must be [{}, {}]",
            cfg.n_layer,
            cfg.n_exp
        );
        if let Some(rm) = remap {
            ensure!(rm.len() == cfg.n_layer * cfg.n_exp, "remap size mismatch");
            ensure!(
                rm.iter().all(|&s| s >= 0 && (s as usize) < m.n_slots),
                "remap slot out of range {}",
                m.n_slots
            );
        }
        let d = cfg.d;
        let hd = d / cfg.heads;
        ensure!(hd * cfg.heads == d, "heads must divide d");
        let w = &m.weights;
        let pos = w.get("pos")?;
        let embed = w.get("embed")?;
        let mut cs = seq_cache_mut(existing, self.name())?;
        let t0 = cs.t();
        ensure!(
            cs.counts().len() == cfg.n_layer
                && cs.counts().iter().all(|ct| ct.len() == m.n_slots),
            "dispatch counts must cover {} slots per layer",
            m.n_slots
        );
        ensure!(
            pos.shape()[0] >= t0 + c,
            "sequence length {} exceeds t_max {}",
            t0 + c,
            pos.shape()[0]
        );
        for &tok in ids {
            ensure!(
                tok >= 0 && (tok as usize) < cfg.vocab,
                "token id {tok} out of vocab range {}",
                cfg.vocab
            );
        }
        match &cs {
            SeqCacheMut::Flat(fc) => {
                ensure!(
                    fc.k.len() == cfg.n_layer && fc.v.len() == cfg.n_layer,
                    "kv cache layer count mismatch"
                );
                ensure!(
                    fc.k.iter().all(|kb| kb.len() == t0 * d)
                        && fc.v.iter().all(|vb| vb.len() == t0 * d),
                    "kv cache length out of sync"
                );
            }
            SeqCacheMut::Paged(pc) => {
                let p = pc.seq.pool().borrow();
                ensure!(
                    p.n_layer() == cfg.n_layer && p.d() == d,
                    "kv pool geometry (n_layer={}, d={}) does not match the model \
                     (n_layer={}, d={})",
                    p.n_layer(),
                    p.d(),
                    cfg.n_layer,
                    d
                );
                ensure!(
                    pc.seq.table().len() == p.blocks_for(t0),
                    "paged kv cache block table out of sync"
                );
                // feasibility for the whole chunk before any allocation:
                // reserved growth first, overflow and a possible tail COW
                // from the best-effort pool
                let fresh = p.blocks_for(t0 + c).saturating_sub(pc.seq.table().len());
                let cow = usize::from(pc.seq.append_block_need() == Some(true));
                let res = fresh.min(pc.seq.reserved_remaining());
                let unres = fresh - res + cow;
                ensure!(
                    p.can_alloc(res, unres),
                    "kv pool exhausted: prefill chunk needs {} more blocks than the \
                     budget allows (raise {})",
                    res + unres,
                    crate::kvpool::KV_BUDGET_ENV
                );
            }
        }
        // paged: claim every slot the chunk needs up front (prepare derives
        // the local offset from the committed length, so the pair must
        // interleave); the feasibility check above means this cannot fail
        // midway in a way that strands the sequence
        let mut slots: Vec<(usize, usize)> = Vec::with_capacity(c);
        if let SeqCacheMut::Paged(pc) = &mut cs {
            for _ in 0..c {
                let slot = pc.seq.prepare_append()?;
                slots.push(slot);
                pc.seq.commit_append();
            }
        }
        // embedding + learned positions at absolute positions t0..t0+c
        let mut h = vec![0f32; c * d];
        for (i, &tok) in ids.iter().enumerate() {
            let e = &embed.data()[(tok as usize) * d..(tok as usize) * d + d];
            let p = &pos.data()[(t0 + i) * d..(t0 + i + 1) * d];
            for j in 0..d {
                h[i * d + j] = e[j] + p[j];
            }
        }
        let threads = self.auto_threads(c);
        let mut row = Vec::new();
        for l in 0..cfg.n_layer {
            let ln1 = layer_tensor(w, l, "ln1")?;
            let x1 = rmsnorm_rows(&h, ln1.data(), d);
            let wq = layer_tensor(w, l, "attn.wq")?;
            let wk = layer_tensor(w, l, "attn.wk")?;
            let wv = layer_tensor(w, l, "attn.wv")?;
            let wo = layer_tensor(w, l, "attn.wo")?;
            // chunk-wide projection GEMMs (row-identical to c single rows)
            let q = mm(&x1, wq.data(), c, d, d, threads);
            let knew = mm(&x1, wk.data(), c, d, d, threads);
            let vnew = mm(&x1, wv.data(), c, d, d, threads);
            // causal attention token by token: each row appends its own
            // K/V first, then scores against positions 0..=t0+i — the
            // exact per-position accumulation of the whole-prompt forward
            let mut ctx = vec![0f32; c * d];
            match &mut cs {
                SeqCacheMut::Flat(fc) => {
                    for i in 0..c {
                        fc.k[l].extend_from_slice(&knew[i * d..(i + 1) * d]);
                        fc.v[l].extend_from_slice(&vnew[i * d..(i + 1) * d]);
                        attention_row_cached(
                            cfg,
                            &q[i * d..(i + 1) * d],
                            &fc.k[l],
                            &fc.v[l],
                            t0 + i,
                            &mut ctx[i * d..(i + 1) * d],
                            &mut row,
                        );
                    }
                }
                SeqCacheMut::Paged(pc) => {
                    for i in 0..c {
                        let (blk, local) = slots[i];
                        {
                            let mut p = pc.seq.pool().borrow_mut();
                            p.write_k(blk, l, local, &knew[i * d..(i + 1) * d]);
                            p.write_v(blk, l, local, &vnew[i * d..(i + 1) * d]);
                        }
                        let p = pc.seq.pool().borrow();
                        attention_row_paged(
                            cfg,
                            &q[i * d..(i + 1) * d],
                            &p,
                            pc.seq.table(),
                            l,
                            t0 + i,
                            &mut ctx[i * d..(i + 1) * d],
                            &mut row,
                        );
                    }
                }
            }
            let a = mm(&ctx, wo.data(), c, d, d, threads);
            for (hv, av) in h.iter_mut().zip(&a) {
                *hv += av;
            }
            let ln2 = layer_tensor(w, l, "ln2")?;
            let hf = rmsnorm_rows(&h, ln2.data(), d);
            let mask_l = &mask[l * cfg.n_exp..(l + 1) * cfg.n_exp];
            let remap_l = remap.map(|rm| &rm[l * cfg.n_exp..(l + 1) * cfg.n_exp]);
            let y = moe_chunk(
                cfg, w, l, &hf, t0, c, mask_l, remap_l, m.n_slots, threads, self.shards,
                &mut cs, Some(&m.routing),
            )?;
            for (hv, yv) in h.iter_mut().zip(&y) {
                *hv += yv;
            }
        }
        if let SeqCacheMut::Flat(fc) = &mut cs {
            fc.t += c; // paged length was committed per prepared slot above
        }
        let ln_f = w.get("ln_f")?;
        let hn = rmsnorm_rows(&h, ln_f.data(), d);
        let last = &hn[(c - 1) * d..c * d];
        Ok(mm(last, m.embed_t(cfg)?, 1, d, cfg.vocab, threads))
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn load_model(&self, weights: &Weights, n_slots: usize) -> Result<Box<dyn ModelState>> {
        ensure!(
            weights.n_experts()? == n_slots,
            "weight set has {} expert slots, expected {n_slots}",
            weights.n_experts()?
        );
        Ok(Box::new(NativeModel {
            weights: weights.clone(),
            n_slots,
            embed_t: OnceLock::new(),
            // hashed once per load: every KV fingerprint and the variant
            // registry's identity key derive from this
            weights_fp: weights.content_hash(),
            routing: RoutingStats::new(self.cfg.n_layer, n_slots),
        }))
    }

    fn run_logits(
        &self,
        state: &dyn ModelState,
        ids: &[i32],
        b: usize,
        t: usize,
        mask: &[f32],
        remap: Option<&[i32]>,
    ) -> Result<Tensor> {
        let m: &NativeModel = downcast_state(state, self.name())?;
        forward_logits_with(
            &self.cfg,
            &m.weights,
            ids,
            b,
            t,
            mask,
            remap,
            m.n_slots,
            self.auto_threads(b * t),
        )
    }

    fn run_calib(
        &self,
        state: &dyn ModelState,
        ids: &[i32],
        b: usize,
        t: usize,
        t_sub: usize,
        t_act: usize,
    ) -> Result<Vec<Tensor>> {
        let m: &NativeModel = downcast_state(state, self.name())?;
        ensure!(
            m.n_slots == self.cfg.n_exp,
            "calibration runs on the full {}-expert layout",
            self.cfg.n_exp
        );
        forward_calib_with(
            &self.cfg,
            &m.weights,
            ids,
            b,
            t,
            t_sub,
            t_act,
            self.auto_threads(b * t),
        )
    }

    fn run_prefill(
        &self,
        state: &dyn ModelState,
        ids: &[i32],
        opts: PrefillOpts<'_>,
    ) -> Result<(Option<Box<dyn KvCache>>, Vec<f32>)> {
        let m: &NativeModel = downcast_state(state, self.name())?;
        let PrefillOpts { mask, remap, cache, resume_from } = opts;
        if let Some(existing) = resume_from {
            // chunked prefill: append the next chunk of a longer prompt to
            // whichever cache flavour the sequence already lives in
            let logits = self.prefill_append(m, ids, mask, remap, existing)?;
            return Ok((None, logits));
        }
        match cache {
            CacheMode::Flat => {
                let parts = self.prefill_forward(m, ids, mask, remap)?;
                let PrefillParts { mut k, mut v, counts, logits, .. } = parts;
                // Reserve the decode headroom once, up to the model's
                // context window: the per-step `extend_from_slice` then
                // never regrows the buffer, so steady-state decode is
                // reallocation-free (pinned by the `kv_cache_sweep`
                // microbench's reallocs column). This trades worst-case
                // residency — exactly `kv_cache_bytes(t_max)`, the bound
                // any decode can reach — for the zero-realloc guarantee;
                // memory-conscious serving uses the paged pool instead,
                // where residency is whole blocks as actually consumed.
                let headroom = self.cfg.t_max.saturating_sub(ids.len()) * self.cfg.d;
                for buf in k.iter_mut().chain(v.iter_mut()) {
                    buf.reserve_exact(headroom);
                }
                Ok((
                    Some(Box::new(NativeKvCache { t: ids.len(), k, v, counts })),
                    logits,
                ))
            }
            CacheMode::Paged { pool, reserve_tokens } => {
                let cfg = &self.cfg;
                {
                    let p = pool.borrow();
                    ensure!(
                        p.n_layer() == cfg.n_layer && p.d() == cfg.d,
                        "kv pool geometry (n_layer={}, d={}) does not match the model \
                         (n_layer={}, d={})",
                        p.n_layer(),
                        p.d(),
                        cfg.n_layer,
                        cfg.d
                    );
                }
                // Reserve the worst-case block count BEFORE the forward: a
                // prompt the budget cannot host must fail without burning
                // compute, and an admitted sequence can never fail an
                // allocation mid-decode.
                let reserve_len = reserve_tokens.max(ids.len()).min(cfg.t_max);
                let reserve_blocks = pool.blocks_for(reserve_len);
                let mut seq = PagedSeq::new(pool, reserve_blocks)?;
                let parts = self.prefill_forward(m, ids, mask, remap)?;
                // Prefix sharing is only bit-safe between drop-free
                // prefills: the capacity-drop rule depends on the prompt's
                // total length, so a dropped token would make the "same"
                // prefix length-dependent (see the kvpool module docs).
                // Synthesized sets are drop-free.
                let drop_free = parts
                    .counts
                    .iter()
                    .all(|layer| layer.iter().all(|&n| n <= parts.cap));
                let fp = variant_fingerprint(
                    mask,
                    remap,
                    m.n_slots,
                    m.weights.is_quantized(),
                    m.weights_fp,
                );
                seq.fill_from_rows(ids, fp, drop_free, &parts.k, &parts.v)?;
                Ok((
                    Some(Box::new(NativePagedKvCache { seq, counts: parts.counts })),
                    parts.logits,
                ))
            }
        }
    }

    fn run_decode(
        &self,
        state: &dyn ModelState,
        cache: &mut dyn KvCache,
        token: i32,
        mask: &[f32],
        remap: Option<&[i32]>,
    ) -> Result<Vec<f32>> {
        // a batch of one: the single-sequence path IS the batched path, so
        // batched-vs-sequential bit-identity holds by construction (and the
        // decode hot path shares the prefill thread-gating policy instead
        // of the old hardcoded threads = 1)
        let mut caches: [&mut dyn KvCache; 1] = [cache];
        let mut rows = self.run_decode_batch(state, &mut caches, &[token], mask, remap)?;
        Ok(rows.pop().expect("one logits row per sequence"))
    }

    fn run_decode_batch(
        &self,
        state: &dyn ModelState,
        caches: &mut [&mut dyn KvCache],
        tokens: &[i32],
        mask: &[f32],
        remap: Option<&[i32]>,
    ) -> Result<Vec<Vec<f32>>> {
        let threads = self.auto_threads(caches.len());
        self.run_decode_batch_with(state, caches, tokens, mask, remap, threads)
    }

    fn run_verify(
        &self,
        state: &dyn ModelState,
        caches: &mut [&mut dyn KvCache],
        tokens: &[&[i32]],
        mask: &[f32],
        remap: Option<&[i32]>,
    ) -> Result<Vec<VerifyOut>> {
        // thread-gate on the flattened row count: a verify of R total
        // positions does the work of an R-sequence decode step
        let rows: usize = tokens.iter().map(|r| r.len()).sum();
        let threads = self.auto_threads(rows);
        self.run_verify_batch_with(state, caches, tokens, mask, remap, threads)
    }

    fn snapshot_cache(&self, cache: &dyn KvCache) -> Result<CacheSnapshot> {
        if let Some(fc) = cache.as_any().downcast_ref::<NativeKvCache>() {
            Ok(CacheSnapshot::new(fc.t, fc.counts.clone()))
        } else if let Some(pc) = cache.as_any().downcast_ref::<NativePagedKvCache>() {
            Ok(CacheSnapshot::new(pc.seq.seq_len(), pc.counts.clone()))
        } else {
            Err(anyhow!("kv cache was not created by the {} backend", self.name()))
        }
    }

    fn rollback_cache(&self, cache: &mut dyn KvCache, snap: &CacheSnapshot) -> Result<()> {
        let d = self.cfg.d;
        let len = snap.len();
        let mut cs = seq_cache_mut(cache, self.name())?;
        ensure!(
            len <= cs.t(),
            "rollback target {len} is ahead of the cache (length {}); snapshots only \
             roll backwards",
            cs.t()
        );
        // the snapshot's bookkeeping must describe the same layer/slot
        // geometry as the cache it restores — a snapshot taken from a
        // different variant's cache would silently corrupt the capacity
        // queue, so reject it up front
        ensure!(
            snap.counts().len() == cs.counts().len()
                && snap.counts().iter().zip(cs.counts()).all(|(a, b)| a.len() == b.len()),
            "snapshot dispatch-count geometry does not match the cache"
        );
        match &mut cs {
            SeqCacheMut::Flat(fc) => {
                // Vec::truncate never shrinks capacity, so the decode
                // headroom reserved at prefill survives the rollback and
                // re-decoding stays reallocation-free
                for (kb, vb) in fc.k.iter_mut().zip(fc.v.iter_mut()) {
                    kb.truncate(len * d);
                    vb.truncate(len * d);
                }
                fc.t = len;
                fc.counts = snap.counts().to_vec();
            }
            SeqCacheMut::Paged(pc) => {
                // releases now-unused tail blocks and restores their
                // reservation so re-decoding the same span cannot fail
                pc.seq.truncate_to(len)?;
                pc.counts = snap.counts().to_vec();
            }
        }
        Ok(())
    }

    fn routing_stats(&self, state: &dyn ModelState) -> Option<RoutingSnapshot> {
        let m: &NativeModel = downcast_state(state, self.name()).ok()?;
        Some(m.routing.snapshot())
    }
}

/// Work-gated matmul: route through the blocked parallel kernel only when
/// this product clears the auto-dispatch threshold (a scoped spawn costs
/// ~50µs; tiny products must stay serial to win).
fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) -> Vec<f32> {
    let t = if m * k * n >= parallel::PAR_AUTO_WORK {
        threads
    } else {
        1
    };
    matmul_blocked_with(a, b, m, k, n, t)
}

/// [`mm`] for an int8 per-row-quantized B — same auto-gate policy, routed
/// through [`crate::tensor::matmul_q8_with`] (bit-identical at any thread
/// count).
fn mm_q8(
    a: &[f32],
    q: &[i8],
    scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    let t = if m * k * n >= parallel::PAR_AUTO_WORK {
        threads
    } else {
        1
    };
    matmul_q8_with(a, q, scales, m, k, n, t)
}

/// `x * sigmoid(x)` (`jax.nn.silu`).
#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Row-wise RMSNorm: `x * w * rsqrt(mean(x²) + eps)` per `d`-row.
fn rmsnorm_rows(h: &[f32], wln: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0f32; h.len()];
    for (dst, src) in out.chunks_mut(d).zip(h.chunks(d)) {
        let ms = src.iter().map(|x| x * x).sum::<f32>() / d as f32;
        let s = 1.0 / (ms + RMS_EPS).sqrt();
        for j in 0..d {
            dst[j] = src[j] * wln[j] * s;
        }
    }
    out
}

fn layer_tensor<'a>(w: &'a Weights, layer: usize, suffix: &str) -> Result<&'a Tensor> {
    w.get(&Weights::layer_key(layer, suffix))
}

/// Token embedding + learned positions: `h[i] = embed[ids[i]] + pos[i % t]`.
fn embed_tokens(cfg: &ModelCfg, w: &Weights, ids: &[i32], t: usize) -> Result<Vec<f32>> {
    let d = cfg.d;
    let embed = w.get("embed")?;
    ensure!(
        embed.shape() == [cfg.vocab, d],
        "embed shape {:?} != [{}, {d}]",
        embed.shape(),
        cfg.vocab
    );
    let pos = w.get("pos")?;
    ensure!(pos.shape()[0] >= t, "sequence length {t} exceeds t_max {}", pos.shape()[0]);
    let mut h = vec![0f32; ids.len() * d];
    for (i, &id) in ids.iter().enumerate() {
        ensure!(
            id >= 0 && (id as usize) < cfg.vocab,
            "token id {id} out of vocab range {}",
            cfg.vocab
        );
        let e = &embed.data()[(id as usize) * d..(id as usize) * d + d];
        let p = &pos.data()[(i % t) * d..(i % t) * d + d];
        for j in 0..d {
            h[i * d + j] = e[j] + p[j];
        }
    }
    Ok(h)
}

/// Causal multi-head self-attention over one `[t, d]` sequence,
/// pre-projected input `x`; returns the `wo`-projected output plus the
/// K/V projections (`[t, d]` each) so prefill can seed a [`NativeKvCache`]
/// at zero extra cost (scoring callers just drop them).
fn attention_seq(
    cfg: &ModelCfg,
    w: &Weights,
    layer: usize,
    x: &[f32],
    t: usize,
    threads: usize,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
    let d = cfg.d;
    let hd = d / cfg.heads;
    ensure!(hd * cfg.heads == d, "heads must divide d");
    let wq = layer_tensor(w, layer, "attn.wq")?;
    let wk = layer_tensor(w, layer, "attn.wk")?;
    let wv = layer_tensor(w, layer, "attn.wv")?;
    let wo = layer_tensor(w, layer, "attn.wo")?;
    let q = mm(x, wq.data(), t, d, d, threads);
    let k = mm(x, wk.data(), t, d, d, threads);
    let v = mm(x, wv.data(), t, d, d, threads);
    let scale = 1.0 / (hd as f32).sqrt();
    let mut ctx = vec![0f32; t * d];
    let mut row = Vec::with_capacity(t);
    for head in 0..cfg.heads {
        let off = head * hd;
        for i in 0..t {
            let qi = &q[i * d + off..i * d + off + hd];
            row.clear();
            let mut mx = f32::NEG_INFINITY;
            for j in 0..=i {
                let kj = &k[j * d + off..j * d + off + hd];
                let s = dot(qi, kj) * scale;
                mx = mx.max(s);
                row.push(s);
            }
            let mut z = 0f32;
            for s in row.iter_mut() {
                *s = (*s - mx).exp();
                z += *s;
            }
            let out = &mut ctx[i * d + off..i * d + off + hd];
            for (j, &e) in row.iter().enumerate() {
                let a = e / z;
                let vj = &v[j * d + off..j * d + off + hd];
                for u in 0..hd {
                    out[u] += a * vj[u];
                }
            }
        }
    }
    Ok((mm(&ctx, wo.data(), t, d, d, threads), k, v))
}

/// One causal-attention context row for the token at position `i`,
/// scored against the cached K/V rows of positions `0..=i` (the caller
/// has already appended the new token's own K/V). `q` is the token's
/// projected `[d]` query row; the per-head softmax combine is written
/// into `ctx` (`[d]`, assumed zeroed). `row` is caller-owned score
/// scratch so the per-step hot loop stays allocation-free. Operation for
/// operation the `i`-th row of [`attention_seq`], so the result is
/// bit-identical to the full-sequence forward.
fn attention_row_cached(
    cfg: &ModelCfg,
    q: &[f32],
    kbuf: &[f32],
    vbuf: &[f32],
    i: usize,
    ctx: &mut [f32],
    row: &mut Vec<f32>,
) {
    let d = cfg.d;
    let hd = d / cfg.heads;
    let scale = 1.0 / (hd as f32).sqrt();
    for head in 0..cfg.heads {
        let off = head * hd;
        let qi = &q[off..off + hd];
        row.clear();
        let mut mx = f32::NEG_INFINITY;
        for j in 0..=i {
            let kj = &kbuf[j * d + off..j * d + off + hd];
            let s = dot(qi, kj) * scale;
            mx = mx.max(s);
            row.push(s);
        }
        let mut z = 0f32;
        for s in row.iter_mut() {
            *s = (*s - mx).exp();
            z += *s;
        }
        let out = &mut ctx[off..off + hd];
        for (j, &e) in row.iter().enumerate() {
            let a = e / z;
            let vj = &vbuf[j * d + off..j * d + off + hd];
            for u in 0..hd {
                out[u] += a * vj[u];
            }
        }
    }
}

/// Paged analogue of [`attention_row_cached`]: the cached K/V rows of
/// positions `0..=i` are gathered per block through the sequence's block
/// table instead of one contiguous slice. Blocks are visited in position
/// order with locals ascending, so the f32 score → softmax → combine
/// sequence is operation-for-operation the contiguous path's — which is
/// what makes paged logits bit-identical to the flat cache
/// (`rust/tests/kvpool.rs`).
#[allow(clippy::too_many_arguments)]
fn attention_row_paged(
    cfg: &ModelCfg,
    q: &[f32],
    pool: &KvPool,
    table: &[usize],
    layer: usize,
    i: usize,
    ctx: &mut [f32],
    row: &mut Vec<f32>,
) {
    let d = cfg.d;
    let hd = d / cfg.heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let bt = pool.block_tokens();
    let arena = pool.arena();
    for head in 0..cfg.heads {
        let off = head * hd;
        let qi = &q[off..off + hd];
        row.clear();
        let mut mx = f32::NEG_INFINITY;
        let mut remaining = i + 1;
        for &b in table {
            if remaining == 0 {
                break;
            }
            let tokens = remaining.min(bt);
            let ks = pool.k_start(b, layer);
            for j in 0..tokens {
                let kj = &arena[ks + j * d + off..ks + j * d + off + hd];
                let s = dot(qi, kj) * scale;
                mx = mx.max(s);
                row.push(s);
            }
            remaining -= tokens;
        }
        let mut z = 0f32;
        for s in row.iter_mut() {
            *s = (*s - mx).exp();
            z += *s;
        }
        let out = &mut ctx[off..off + hd];
        let mut ri = 0usize;
        let mut remaining = i + 1;
        for &b in table {
            if remaining == 0 {
                break;
            }
            let tokens = remaining.min(bt);
            let vs = pool.v_start(b, layer);
            for j in 0..tokens {
                let a = row[ri] / z;
                ri += 1;
                let vj = &arena[vs + j * d + off..vs + j * d + off + hd];
                for u in 0..hd {
                    out[u] += a * vj[u];
                }
            }
            remaining -= tokens;
        }
    }
}

/// Eq. (3): top-k router selection over one masked logit row as k rounds
/// of argmax (first index wins ties, matching `jnp.argmax`), with softmax
/// over the k selected logits. All buffers are caller-owned scratch so the
/// per-token hot loop stays allocation-free.
fn route_topk(
    masked: &[f32],
    k: usize,
    idx: &mut Vec<usize>,
    probs: &mut Vec<f32>,
    work: &mut Vec<f32>,
) {
    idx.clear();
    probs.clear();
    work.clear();
    work.extend_from_slice(masked);
    for _ in 0..k {
        let mut bi = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (e, &v) in work.iter().enumerate() {
            if v > bv {
                bv = v;
                bi = e;
            }
        }
        idx.push(bi);
        probs.push(bv);
        work[bi] = f32::NEG_INFINITY;
    }
    let mx = probs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0f32;
    for p in probs.iter_mut() {
        *p = (*p - mx).exp();
        z += *p;
    }
    for p in probs.iter_mut() {
        *p /= z;
    }
}

/// SwiGLU over a `[c, d]` token block for one `[d, m] / [m, d]` weight
/// triple: `(silu(X Wg) ⊙ (X Wu)) Wd`. Also returns the intermediate
/// activations when `want_act` (the calibration `act_sub` feature).
fn swiglu_block(
    x: &[f32],
    wg: &[f32],
    wu: &[f32],
    wd: &[f32],
    c: usize,
    d: usize,
    m: usize,
    threads: usize,
    want_act: bool,
) -> (Vec<f32>, Option<Vec<f32>>) {
    let g = mm(x, wg, c, d, m, threads);
    let u = mm(x, wu, c, d, m, threads);
    let mut act = vec![0f32; c * m];
    for i in 0..c * m {
        act[i] = silu(g[i]) * u[i];
    }
    let out = mm(&act, wd, c, m, d, threads);
    (out, if want_act { Some(act) } else { None })
}

/// [`swiglu_block`] over one int8-quantized expert triple: every GEMM runs
/// the folded-scale quantized kernel; the silu/⊙ elementwise math is
/// unchanged f32. No activation capture — calibration always runs on the
/// f32 source (see [`forward_calib_with`]).
#[allow(clippy::too_many_arguments)]
fn swiglu_block_q8(
    x: &[f32],
    qg: (&[i8], &[f32]),
    qu: (&[i8], &[f32]),
    qd: (&[i8], &[f32]),
    c: usize,
    d: usize,
    m: usize,
    threads: usize,
) -> Vec<f32> {
    let g = mm_q8(x, qg.0, qg.1, c, d, m, threads);
    let u = mm_q8(x, qu.0, qu.1, c, d, m, threads);
    let mut act = vec![0f32; c * m];
    for i in 0..c * m {
        act[i] = silu(g[i]) * u[i];
    }
    mm_q8(&act, qd.0, qd.1, c, m, d, threads)
}

/// The int8 expert triple of `layer`, present iff the variant carries
/// quantized expert weights. A partially-quantized triple (some of
/// wg/wu/wd quantized, some f32) is a corrupt variant and errors.
fn quant_experts<'a>(
    w: &'a Weights,
    layer: usize,
) -> Result<Option<(&'a QuantTensor, &'a QuantTensor, &'a QuantTensor)>> {
    let wg = w.quant_opt(&Weights::layer_key(layer, "exp.wg"));
    let wu = w.quant_opt(&Weights::layer_key(layer, "exp.wu"));
    let wd = w.quant_opt(&Weights::layer_key(layer, "exp.wd"));
    match (wg, wu, wd) {
        (Some(g), Some(u), Some(dn)) => Ok(Some((g, u, dn))),
        (None, None, None) => Ok(None),
        _ => Err(anyhow!(
            "layer {layer}: partially quantized expert triple (wg/wu/wd must all be int8 or all f32)"
        )),
    }
}

/// One SMoE FFN block over `tok` flattened tokens: router → top-k →
/// capacity dispatch → per-expert SwiGLU → gated combine (+ the shared
/// expert for `dssim`). Returns `y` with `y.len() == tok * d`.
///
/// `counts`/`cap` externalise the capacity queue: scoring callers pass a
/// fresh all-zero `counts` and `cfg.capacity(tok, n_slots)`; the
/// incremental decode path passes the cumulative counts carried in its
/// [`NativeKvCache`] (so the new token's queue position matches the
/// token-major rule of a full forward over the whole prefix) and the
/// capacity at the *current total* sequence length.
#[allow(clippy::too_many_arguments)]
fn moe_layer(
    cfg: &ModelCfg,
    w: &Weights,
    layer: usize,
    hf: &[f32],
    tok: usize,
    mask_l: &[f32],
    remap_l: Option<&[i32]>,
    n_slots: usize,
    threads: usize,
    shards: usize,
    counts: &mut [usize],
    cap: usize,
    stats: Option<&RoutingStats>,
) -> Result<Vec<f32>> {
    let d = cfg.d;
    let n = cfg.n_exp;
    let router = layer_tensor(w, layer, "router")?;
    ensure!(router.shape() == [d, n], "router shape mismatch at layer {layer}");
    ensure!(counts.len() == n_slots, "dispatch counts must cover {n_slots} slots");
    let logits = mm(hf, router.data(), tok, d, n, threads);
    // Dispatch: queue position per expert in token-major (T*k) order —
    // the same cumulative-count rule as the Pallas dispatch, so the same
    // tokens are dropped at capacity.
    let mut per_slot: Vec<Vec<(usize, f32)>> = vec![Vec::new(); n_slots];
    let mut masked = vec![0f32; n];
    let mut idx = Vec::with_capacity(cfg.k);
    let mut probs = Vec::with_capacity(cfg.k);
    let mut scratch = Vec::with_capacity(n);
    for ti in 0..tok {
        let row = &logits[ti * n..(ti + 1) * n];
        for e in 0..n {
            masked[e] = row[e] + mask_l[e];
        }
        route_topk(&masked, cfg.k, &mut idx, &mut probs, &mut scratch);
        for j in 0..cfg.k {
            let slot = match remap_l {
                Some(rm) => rm[idx[j]] as usize,
                None => idx[j],
            };
            ensure!(slot < n_slots, "remap slot {slot} out of range {n_slots}");
            let q = counts[slot];
            counts[slot] += 1;
            if q < cap {
                per_slot[slot].push((ti, probs[j]));
            }
        }
    }
    moe_execute(cfg, w, layer, hf, tok, &per_slot, n_slots, threads, shards, stats)
}

/// Execute a routed dispatch: one grouped SwiGLU GEMM per expert over its
/// gathered token rows, gated-combined back into `y` in
/// (expert-ascending, queue-order) order, plus `dssim`'s always-on shared
/// expert. Shared **verbatim** by the scoring/prefill path
/// ([`moe_layer`]) and the batched decode/verify path ([`moe_verify`]), so
/// the FFN execution semantics have a single source of truth — only the
/// routing loops differ between the two (one capacity queue spanning a
/// whole scoring batch vs. one per sequence), which is what keeps the
/// batched-vs-sequential bit-identity contract safe against one-sided
/// edits.
#[allow(clippy::too_many_arguments)]
fn moe_execute(
    cfg: &ModelCfg,
    w: &Weights,
    layer: usize,
    hf: &[f32],
    tok: usize,
    per_slot: &[Vec<(usize, f32)>],
    n_slots: usize,
    threads: usize,
    shards: usize,
    stats: Option<&RoutingStats>,
) -> Result<Vec<f32>> {
    // Single observation point for live routing stats: every serving path
    // (prefill, chunked prefill, decode, verify) flows through here, so
    // one `record` covers them all; scoring callers pass `None`.
    if let Some(st) = stats {
        st.record(layer, per_slot, tok);
    }
    let d = cfg.d;
    // Expert-parallel sharding splits the worker budget: each of the (up
    // to) `shards` concurrent expert blocks runs its GEMMs with the
    // per-shard remainder of `threads`, so the total worker count stays
    // near `threads` and inner outputs stay bit-identical regardless (the
    // `crate::parallel` contract).
    let inner = (threads / shards.max(1)).max(1);
    // Per-variant kernel selection: a quantized variant carries its expert
    // triples in the int8 section, and every caller (scoring prefill,
    // batched decode, verify, chunked prefill) flows through this single
    // dispatch point. Router/attention/shared-expert/head stay f32.
    if let Some((qwg, qwu, qwd)) = quant_experts(w, layer)? {
        ensure!(qwg.shape()[0] == n_slots, "expert tensors must have {n_slots} slots");
        let m = qwg.shape()[2];
        let outs = shard_expert_blocks(shards, per_slot, |e, assigned| {
            let c = assigned.len();
            let rows: Vec<usize> = assigned.iter().map(|&(ti, _)| ti).collect();
            let x = gather_rows(hf, d, &rows);
            swiglu_block_q8(
                &x,
                qwg.index_slices(e),
                qwu.index_slices(e),
                qwd.index_slices(e),
                c,
                d,
                m,
                inner,
            )
        });
        let mut y = vec![0f32; tok * d];
        combine_expert_blocks(per_slot, &outs, d, &mut y);
        if cfg.shared {
            add_shared_expert(cfg, w, layer, hf, tok, threads, &mut y)?;
        }
        return Ok(y);
    }
    let wg = layer_tensor(w, layer, "exp.wg")?;
    let wu = layer_tensor(w, layer, "exp.wu")?;
    let wd = layer_tensor(w, layer, "exp.wd")?;
    ensure!(wg.shape()[0] == n_slots, "expert tensors must have {n_slots} slots");
    let m = wg.shape()[2];
    let outs = shard_expert_blocks(shards, per_slot, |e, assigned| {
        let c = assigned.len();
        let rows: Vec<usize> = assigned.iter().map(|&(ti, _)| ti).collect();
        let x = gather_rows(hf, d, &rows);
        let (out, _) = swiglu_block(
            &x,
            &wg.data()[e * d * m..(e + 1) * d * m],
            &wu.data()[e * d * m..(e + 1) * d * m],
            &wd.data()[e * m * d..(e + 1) * m * d],
            c,
            d,
            m,
            inner,
            false,
        );
        out
    });
    let mut y = vec![0f32; tok * d];
    combine_expert_blocks(per_slot, &outs, d, &mut y);
    if cfg.shared {
        add_shared_expert(cfg, w, layer, hf, tok, threads, &mut y)?;
    }
    Ok(y)
}

/// Compute every non-empty expert's output block, partitioned across
/// `shards` workers ([`parallel::par_map_chunks`] over the slot index —
/// contiguous slot ranges per worker, results returned in slot order).
/// `f(e, assigned)` must be the pure per-expert gather + SwiGLU; empty
/// slots yield `None` without calling `f`. With `shards <= 1` this is a
/// plain in-order sweep with no spawns — the serial path.
fn shard_expert_blocks<F>(
    shards: usize,
    per_slot: &[Vec<(usize, f32)>],
    f: F,
) -> Vec<Option<Vec<f32>>>
where
    F: Fn(usize, &[(usize, f32)]) -> Vec<f32> + Sync,
{
    let block = |e: usize| {
        let assigned = &per_slot[e];
        if assigned.is_empty() {
            None
        } else {
            Some(f(e, assigned))
        }
    };
    if shards <= 1 {
        return (0..per_slot.len()).map(block).collect();
    }
    parallel::par_map_chunks(shards, per_slot.len(), |r| {
        r.map(block).collect::<Vec<_>>()
    })
    .into_iter()
    .flatten()
    .collect()
}

/// The gated combine: scatter every expert block back into `y` in
/// (expert-ascending, queue-order) order. This stays a single sequential
/// sweep at any shard count — it is the only place expert outputs meet in
/// f32 accumulation, so running it serially in the serial path's exact
/// order is what makes expert-parallel sharding bit-identical.
fn combine_expert_blocks(
    per_slot: &[Vec<(usize, f32)>],
    outs: &[Option<Vec<f32>>],
    d: usize,
    y: &mut [f32],
) {
    for (assigned, out) in per_slot.iter().zip(outs) {
        let Some(out) = out else { continue };
        for (ri, &(ti, p)) in assigned.iter().enumerate() {
            for j in 0..d {
                y[ti * d + j] += p * out[ri * d + j];
            }
        }
    }
}

/// One SMoE FFN block over a **verify batch** (and, at k = 1 runs, the
/// decode batch): `hf` holds the flattened `[sum(k_s), d]` rows of every
/// sequence's token run, sequence-major with positions in order. Each
/// sequence carries its own cumulative dispatch counts, and each
/// *position* routes at its own capacity (`capacity(t0_s + i + 1)` — the
/// decode convention, since capacity depends on the token's own total
/// length), so a k-token verify routes exactly like the same tokens
/// decoded one step at a time.
///
/// The routing GEMM is shared across the whole flattened batch; the
/// selection, the token-major queue update and the gated combine happen
/// per sequence per position in exactly the order the single-sequence
/// [`moe_layer`] uses — only the expert execution is fused: routed rows
/// from all sequences and positions are gathered into one block per
/// expert and run through a single SwiGLU GEMM. The combine then
/// scatters rows back in (expert-ascending, queue-order) order, which is
/// the same per-row f32 accumulation sequence as separate calls — hence
/// bit-identity.
///
/// After routing position `i` of sequence `s` at this layer, the
/// sequence's cumulative counts are cloned into `ckpts[s][i]` — called
/// once per layer in layer order, this builds each position's
/// `[n_layer][n_slots]` snapshot for speculative rollback.
#[allow(clippy::too_many_arguments)]
fn moe_verify(
    cfg: &ModelCfg,
    w: &Weights,
    layer: usize,
    hf: &[f32],
    tokens: &[&[i32]],
    t0s: &[usize],
    mask_l: &[f32],
    remap_l: Option<&[i32]>,
    n_slots: usize,
    threads: usize,
    shards: usize,
    cs: &mut [SeqCacheMut],
    ckpts: &mut [Vec<Vec<Vec<usize>>>],
    stats: Option<&RoutingStats>,
) -> Result<Vec<f32>> {
    let d = cfg.d;
    let n = cfg.n_exp;
    let rtot: usize = tokens.iter().map(|r| r.len()).sum();
    let router = layer_tensor(w, layer, "router")?;
    ensure!(router.shape() == [d, n], "router shape mismatch at layer {layer}");
    let logits = mm(hf, router.data(), rtot, d, n, threads);
    let mut per_slot: Vec<Vec<(usize, f32)>> = vec![Vec::new(); n_slots];
    let mut masked = vec![0f32; n];
    let mut idx = Vec::with_capacity(cfg.k);
    let mut probs = Vec::with_capacity(cfg.k);
    let mut scratch = Vec::with_capacity(n);
    let mut r0 = 0usize;
    for (s, c) in cs.iter_mut().enumerate() {
        ensure!(
            c.counts()[layer].len() == n_slots,
            "dispatch counts must cover {n_slots} slots"
        );
        for i in 0..tokens[s].len() {
            // capacity at THIS token's new total length, against its
            // sequence's cumulative token-major queue — identical to the
            // sequential decode path
            let cap = cfg.capacity(t0s[s] + i + 1, n_slots);
            let row = &logits[(r0 + i) * n..(r0 + i + 1) * n];
            for e in 0..n {
                masked[e] = row[e] + mask_l[e];
            }
            route_topk(&masked, cfg.k, &mut idx, &mut probs, &mut scratch);
            let counts = c.counts_mut(layer);
            for j in 0..cfg.k {
                let slot = match remap_l {
                    Some(rm) => rm[idx[j]] as usize,
                    None => idx[j],
                };
                ensure!(slot < n_slots, "remap slot {slot} out of range {n_slots}");
                let qpos = counts[slot];
                counts[slot] += 1;
                if qpos < cap {
                    per_slot[slot].push((r0 + i, probs[j]));
                }
            }
            ckpts[s][i].push(counts.to_vec());
        }
        r0 += tokens[s].len();
    }
    // grouped execution: all rows routed to an expert run as one block,
    // through the exact code the scoring/prefill path uses
    moe_execute(cfg, w, layer, hf, rtot, &per_slot, n_slots, threads, shards, stats)
}

/// One SMoE FFN block over a **prompt chunk** of a single resumed
/// sequence: `hf` holds `[c, d]` rows at absolute positions
/// `t0 .. t0 + c`. Routing mirrors the decode path token by token —
/// capacity at each token's own cumulative length
/// (`capacity(t0 + i + 1)`), charged against the sequence's cumulative
/// dispatch counts — so a chunked prefill routes exactly like the same
/// tokens decoded one step at a time, at any chunking. On drop-free
/// token sets (the bit-identity proviso on
/// [`super::Backend::run_prefill`]) this also matches the whole-prompt
/// forward, whose drop rule uses the final total length for every token.
/// Expert execution is the shared [`moe_execute`], combining in the
/// (expert-ascending, queue-order) order both other paths use.
#[allow(clippy::too_many_arguments)]
fn moe_chunk(
    cfg: &ModelCfg,
    w: &Weights,
    layer: usize,
    hf: &[f32],
    t0: usize,
    c: usize,
    mask_l: &[f32],
    remap_l: Option<&[i32]>,
    n_slots: usize,
    threads: usize,
    shards: usize,
    cs: &mut SeqCacheMut,
    stats: Option<&RoutingStats>,
) -> Result<Vec<f32>> {
    let d = cfg.d;
    let n = cfg.n_exp;
    let router = layer_tensor(w, layer, "router")?;
    ensure!(router.shape() == [d, n], "router shape mismatch at layer {layer}");
    let logits = mm(hf, router.data(), c, d, n, threads);
    let mut per_slot: Vec<Vec<(usize, f32)>> = vec![Vec::new(); n_slots];
    let mut masked = vec![0f32; n];
    let mut idx = Vec::with_capacity(cfg.k);
    let mut probs = Vec::with_capacity(cfg.k);
    let mut scratch = Vec::with_capacity(n);
    for i in 0..c {
        let cap = cfg.capacity(t0 + i + 1, n_slots);
        let row = &logits[i * n..(i + 1) * n];
        for e in 0..n {
            masked[e] = row[e] + mask_l[e];
        }
        route_topk(&masked, cfg.k, &mut idx, &mut probs, &mut scratch);
        let counts = cs.counts_mut(layer);
        for j in 0..cfg.k {
            let slot = match remap_l {
                Some(rm) => rm[idx[j]] as usize,
                None => idx[j],
            };
            ensure!(slot < n_slots, "remap slot {slot} out of range {n_slots}");
            let qpos = counts[slot];
            counts[slot] += 1;
            if qpos < cap {
                per_slot[slot].push((i, probs[j]));
            }
        }
    }
    moe_execute(cfg, w, layer, hf, c, &per_slot, n_slots, threads, shards, stats)
}

/// `dssim`'s always-on shared expert: `y += swiglu(hf, shared.*)`.
fn add_shared_expert(
    cfg: &ModelCfg,
    w: &Weights,
    layer: usize,
    hf: &[f32],
    tok: usize,
    threads: usize,
    y: &mut [f32],
) -> Result<()> {
    let sg = layer_tensor(w, layer, "shared.wg")?;
    let su = layer_tensor(w, layer, "shared.wu")?;
    let sd = layer_tensor(w, layer, "shared.wd")?;
    let ms = sg.shape()[1];
    let (out, _) =
        swiglu_block(hf, sg.data(), su.data(), sd.data(), tok, cfg.d, ms, threads, false);
    for (yv, ov) in y.iter_mut().zip(&out) {
        *yv += ov;
    }
    Ok(())
}

/// The native `lm_logits` forward with an explicit worker count.
///
/// `ids` is a flattened `[b, t]` batch, `mask` the additive
/// `[n_layer * n_exp]` router mask, `remap` the optional expert→slot
/// table for compact (`n_slots < n_exp`) variants. Returns logits
/// `[b, t, vocab]`. Results are bit-identical at any `threads` (the
/// [`crate::parallel`] determinism contract).
#[allow(clippy::too_many_arguments)]
pub fn forward_logits_with(
    cfg: &ModelCfg,
    w: &Weights,
    ids: &[i32],
    b: usize,
    t: usize,
    mask: &[f32],
    remap: Option<&[i32]>,
    n_slots: usize,
    threads: usize,
) -> Result<Tensor> {
    ensure!(ids.len() == b * t, "ids must be exactly [{b}, {t}]");
    ensure!(
        mask.len() == cfg.n_layer * cfg.n_exp,
        "mask must be [{}, {}]",
        cfg.n_layer,
        cfg.n_exp
    );
    if let Some(rm) = remap {
        ensure!(rm.len() == cfg.n_layer * cfg.n_exp, "remap size mismatch");
    }
    let d = cfg.d;
    let tok = b * t;
    let mut h = embed_tokens(cfg, w, ids, t)?;
    for l in 0..cfg.n_layer {
        let ln1 = layer_tensor(w, l, "ln1")?;
        let x1 = rmsnorm_rows(&h, ln1.data(), d);
        for s in 0..b {
            let (a, _, _) =
                attention_seq(cfg, w, l, &x1[s * t * d..(s + 1) * t * d], t, threads)?;
            for (hv, av) in h[s * t * d..(s + 1) * t * d].iter_mut().zip(&a) {
                *hv += av;
            }
        }
        let ln2 = layer_tensor(w, l, "ln2")?;
        let hf = rmsnorm_rows(&h, ln2.data(), d);
        let mask_l = &mask[l * cfg.n_exp..(l + 1) * cfg.n_exp];
        let remap_l = remap.map(|rm| &rm[l * cfg.n_exp..(l + 1) * cfg.n_exp]);
        let mut counts = vec![0usize; n_slots];
        let cap = cfg.capacity(tok, n_slots);
        // scoring path: `None` stats and `1` shard — offline eval must not
        // pollute the live routing signal a resident serving variant
        // accumulates, and it doubles as the serial reference that the
        // sharded backend paths are pinned bit-identical against
        let y = moe_layer(
            cfg, w, l, &hf, tok, mask_l, remap_l, n_slots, threads, 1, &mut counts, cap,
            None,
        )?;
        for (hv, yv) in h.iter_mut().zip(&y) {
            *hv += yv;
        }
    }
    let ln_f = w.get("ln_f")?;
    let hn = rmsnorm_rows(&h, ln_f.data(), d);
    // weight-tied head: logits = hn @ embedᵀ
    let embed = w.get("embed")?;
    let mut embed_t = vec![0f32; d * cfg.vocab];
    for vtok in 0..cfg.vocab {
        for j in 0..d {
            embed_t[j * cfg.vocab + vtok] = embed.data()[vtok * d + j];
        }
    }
    let logits = mm(&hn, &embed_t, tok, d, cfg.vocab, threads);
    Tensor::new(vec![b, t, cfg.vocab], logits)
}

/// The native `calib` pass with an explicit worker count: dense per-expert
/// compute (every expert on every token, no capacity dispatch) so the
/// Eq. (4) statistics are exact. Returns the 8 stacked `[L, ...]` tensors
/// in the order [`crate::calib`] unpacks.
#[allow(clippy::too_many_arguments)]
pub fn forward_calib_with(
    cfg: &ModelCfg,
    w: &Weights,
    ids: &[i32],
    b: usize,
    t: usize,
    t_sub: usize,
    t_act: usize,
    threads: usize,
) -> Result<Vec<Tensor>> {
    ensure!(ids.len() == b * t, "ids must be exactly [{b}, {t}]");
    ensure!(
        !w.is_quantized(),
        "calibration needs dense per-expert f32 activations; this variant's expert \
         weights are int8-quantized — calibrate on the f32 source and re-quantize"
    );
    let tok = b * t;
    ensure!(
        t_sub >= 1 && t_sub <= tok && t_act >= 1 && t_act <= t_sub,
        "need 1 <= t_act ({t_act}) <= t_sub ({t_sub}) <= tokens ({tok})"
    );
    let d = cfg.d;
    let n = cfg.n_exp;
    let m = cfg.m;
    let stride = tok / t_sub;
    let sub_idx: Vec<usize> = (0..t_sub).map(|i| i * stride).collect();
    let act_idx = &sub_idx[..t_act];

    let nl = cfg.n_layer;
    let mut mean_out = vec![0f32; nl * n * d];
    let mut counts = vec![0f32; nl * n];
    let mut probs_sum = vec![0f32; nl * n];
    let mut gate_sum = vec![0f32; nl * n];
    let mut rl_sub = vec![0f32; nl * t_sub * n];
    let mut raw_sub = vec![0f32; nl * n * t_sub * d];
    let mut act_sub = vec![0f32; nl * n * t_act * m];
    let mut hid_sub = vec![0f32; nl * t_sub * d];

    let mut h = embed_tokens(cfg, w, ids, t)?;
    let mut idx = Vec::with_capacity(cfg.k);
    let mut probs = Vec::with_capacity(cfg.k);
    let mut scratch = Vec::with_capacity(n);
    for l in 0..nl {
        let ln1 = layer_tensor(w, l, "ln1")?;
        let x1 = rmsnorm_rows(&h, ln1.data(), d);
        for s in 0..b {
            let (a, _, _) =
                attention_seq(cfg, w, l, &x1[s * t * d..(s + 1) * t * d], t, threads)?;
            for (hv, av) in h[s * t * d..(s + 1) * t * d].iter_mut().zip(&a) {
                *hv += av;
            }
        }
        let ln2 = layer_tensor(w, l, "ln2")?;
        let hf = rmsnorm_rows(&h, ln2.data(), d);
        let router = layer_tensor(w, l, "router")?;
        let logits = mm(&hf, router.data(), tok, d, n, threads);

        // dense per-expert outputs + intermediate activations
        let wg = layer_tensor(w, l, "exp.wg")?;
        let wu = layer_tensor(w, l, "exp.wu")?;
        let wd = layer_tensor(w, l, "exp.wd")?;
        ensure!(wg.shape()[0] == n, "calibration needs the full {n}-expert layout");
        let mut outs: Vec<Vec<f32>> = Vec::with_capacity(n);
        for e in 0..n {
            let (out, act) = swiglu_block(
                &hf,
                &wg.data()[e * d * m..(e + 1) * d * m],
                &wu.data()[e * d * m..(e + 1) * d * m],
                &wd.data()[e * m * d..(e + 1) * m * d],
                tok,
                d,
                m,
                threads,
                true,
            );
            let acc = &mut mean_out[(l * n + e) * d..(l * n + e + 1) * d];
            for ti in 0..tok {
                for j in 0..d {
                    acc[j] += out[ti * d + j];
                }
            }
            for v in acc.iter_mut() {
                *v /= tok as f32;
            }
            let raw = &mut raw_sub[((l * n + e) * t_sub) * d..((l * n + e + 1) * t_sub) * d];
            for (si, &ti) in sub_idx.iter().enumerate() {
                raw[si * d..(si + 1) * d].copy_from_slice(&out[ti * d..(ti + 1) * d]);
            }
            let act = act.expect("want_act requested");
            let dst = &mut act_sub[((l * n + e) * t_act) * m..((l * n + e + 1) * t_act) * m];
            for (si, &ti) in act_idx.iter().enumerate() {
                dst[si * m..(si + 1) * m].copy_from_slice(&act[ti * m..(ti + 1) * m]);
            }
            outs.push(out);
        }

        // routing statistics + dense gated combine
        let mut y = vec![0f32; tok * d];
        for ti in 0..tok {
            let row = &logits[ti * n..(ti + 1) * n];
            route_topk(row, cfg.k, &mut idx, &mut probs, &mut scratch);
            for j in 0..cfg.k {
                let e = idx[j];
                counts[l * n + e] += 1.0;
                gate_sum[l * n + e] += probs[j];
                let dst = &mut y[ti * d..(ti + 1) * d];
                let src = &outs[e][ti * d..(ti + 1) * d];
                for u in 0..d {
                    dst[u] += probs[j] * src[u];
                }
            }
            // full-softmax router scores (S-prune criterion)
            let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0f32;
            for &v in row {
                z += (v - mx).exp();
            }
            for e in 0..n {
                probs_sum[l * n + e] += (row[e] - mx).exp() / z;
            }
        }
        for (si, &ti) in sub_idx.iter().enumerate() {
            let dst = &mut rl_sub[(l * t_sub + si) * n..(l * t_sub + si + 1) * n];
            dst.copy_from_slice(&logits[ti * n..(ti + 1) * n]);
            let hdst = &mut hid_sub[(l * t_sub + si) * d..(l * t_sub + si + 1) * d];
            hdst.copy_from_slice(&hf[ti * d..(ti + 1) * d]);
        }
        if cfg.shared {
            add_shared_expert(cfg, w, l, &hf, tok, threads, &mut y)?;
        }
        for (hv, yv) in h.iter_mut().zip(&y) {
            *hv += yv;
        }
    }
    Ok(vec![
        Tensor::new(vec![nl, n, d], mean_out)?,
        Tensor::new(vec![nl, n], counts)?,
        Tensor::new(vec![nl, n], probs_sum)?,
        Tensor::new(vec![nl, n], gate_sum)?,
        Tensor::new(vec![nl, t_sub, n], rl_sub)?,
        Tensor::new(vec![nl, n, t_sub, d], raw_sub)?,
        Tensor::new(vec![nl, n, t_act, m], act_sub)?,
        Tensor::new(vec![nl, t_sub, d], hid_sub)?,
    ])
}

/// Convenience wrapper used by tests/benches: auto-threaded scoring
/// forward on the full expert layout with a keep-everything mask.
pub fn forward_logits(
    cfg: &ModelCfg,
    w: &Weights,
    ids: &[i32],
    b: usize,
    t: usize,
) -> Result<Tensor> {
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let threads = NativeBackend::new(cfg.clone()).auto_threads(b * t);
    forward_logits_with(cfg, w, ids, b, t, &mask, None, cfg.n_exp, threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_topk_orders_and_normalises() {
        let mut idx = Vec::new();
        let mut probs = Vec::new();
        let mut work = Vec::new();
        route_topk(&[0.1, 2.0, -1.0, 2.0], 2, &mut idx, &mut probs, &mut work);
        // ties break to the first index, like jnp.argmax
        assert_eq!(idx, vec![1, 3]);
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!((probs[0] - 0.5).abs() < 1e-6, "equal logits split evenly");
    }

    #[test]
    fn route_topk_respects_mask() {
        let mut idx = Vec::new();
        let mut probs = Vec::new();
        let mut work = Vec::new();
        let mask = crate::pipeline::MASK_OFF;
        route_topk(&[5.0 + mask, 1.0, 0.5, 0.0], 2, &mut idx, &mut probs, &mut work);
        assert_eq!(idx, vec![1, 2], "masked expert 0 must lose to live ones");
    }

    #[test]
    fn silu_matches_definition() {
        for x in [-3.0f32, -0.5, 0.0, 0.5, 3.0] {
            let sig = 1.0 / (1.0 + (-x).exp());
            assert!((silu(x) - x * sig).abs() < 1e-6);
        }
        assert_eq!(silu(0.0), 0.0);
    }

    #[test]
    fn rmsnorm_unit_rows() {
        // a row of all-equal values x has mean(x²)=x², so the normalised
        // row is x/|x| * w (up to eps)
        let h = vec![2.0f32, 2.0, 2.0, 2.0, -3.0, -3.0, -3.0, -3.0];
        let wln = vec![1.0f32; 4];
        let out = rmsnorm_rows(&h, &wln, 4);
        for v in &out[..4] {
            assert!((v - 1.0).abs() < 1e-3, "{v}");
        }
        for v in &out[4..] {
            assert!((v + 1.0).abs() < 1e-3, "{v}");
        }
    }

    /// Speculative rollback at the byte level: after verifying a draft
    /// run and rolling back to a checkpoint, the flat cache's private
    /// K/V buffers and dispatch counts are BIT-IDENTICAL to a cache that
    /// was freshly prefilled with prompt + kept-run — not just
    /// behaviourally equivalent (that part lives in
    /// `rust/tests/spec_decode.rs`, which can only see public API).
    #[test]
    fn rollback_restores_kv_bytes_exactly() {
        let cfg = ModelCfg {
            name: "rb".into(),
            n_layer: 2,
            d: 8,
            m: 8,
            n_exp: 4,
            k: 2,
            heads: 2,
            vocab: 24,
            t_max: 32,
            shared: false,
            m_shared: 8,
            cap_factor: 4.0,
            block_c: 4,
        };
        let w = Weights::synthesize(&cfg, 77);
        let backend = NativeBackend::new(cfg.clone());
        let state = backend.load_model(&w, cfg.n_exp).unwrap();
        let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
        let prompt: Vec<i32> = (0..6).map(|i| ((3 + i * 5) % cfg.vocab) as i32).collect();
        let run: Vec<i32> = (0..4).map(|i| ((7 + i * 11) % cfg.vocab) as i32).collect();

        for keep in [1usize, 3] {
            let (cache, _) = backend
                .run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&mask))
                .unwrap();
            let mut cache = cache.unwrap();
            let out = {
                let mut refs: [&mut dyn KvCache; 1] = [cache.as_mut()];
                backend
                    .run_verify(state.as_ref(), &mut refs, &[run.as_slice()], &mask, None)
                    .unwrap()
                    .pop()
                    .unwrap()
            };
            backend.rollback_cache(cache.as_mut(), &out.checkpoints[keep - 1]).unwrap();

            let mut pref = prompt.clone();
            pref.extend_from_slice(&run[..keep]);
            let (fresh, _) = backend
                .run_prefill(state.as_ref(), &pref, PrefillOpts::new(&mask))
                .unwrap();
            let fresh = fresh.unwrap();

            let rolled = cache.as_any().downcast_ref::<NativeKvCache>().unwrap();
            let clean = fresh.as_any().downcast_ref::<NativeKvCache>().unwrap();
            assert_eq!(rolled.t, clean.t, "keep={keep}: cached length");
            assert_eq!(rolled.counts, clean.counts, "keep={keep}: dispatch counts");
            for l in 0..cfg.n_layer {
                let live = rolled.t * cfg.d;
                let eq = |a: &[f32], b: &[f32]| {
                    a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
                };
                assert!(
                    eq(&rolled.k[l][..live], &clean.k[l][..live]),
                    "keep={keep} layer={l}: K bytes diverged after rollback"
                );
                assert!(
                    eq(&rolled.v[l][..live], &clean.v[l][..live]),
                    "keep={keep} layer={l}: V bytes diverged after rollback"
                );
            }
        }
    }

    /// The live routing accumulator counts served traffic (prefill +
    /// decode) and nothing else: the scoring path (`run_logits`) must
    /// leave it untouched, or offline eval would skew the adaptive
    /// recompression signal.
    #[test]
    fn routing_stats_count_served_traffic_only() {
        let cfg = ModelCfg {
            name: "rs".into(),
            n_layer: 2,
            d: 8,
            m: 8,
            n_exp: 4,
            k: 2,
            heads: 2,
            vocab: 24,
            t_max: 32,
            shared: false,
            m_shared: 8,
            cap_factor: 4.0,
            block_c: 4,
        };
        let w = Weights::synthesize(&cfg, 99);
        let backend = NativeBackend::new(cfg.clone());
        let state = backend.load_model(&w, cfg.n_exp).unwrap();
        let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
        let snap0 = backend.routing_stats(state.as_ref()).unwrap();
        assert_eq!(snap0.tokens, 0, "fresh variant starts at zero");

        // scoring does not record
        let ids: Vec<i32> = (0..6).map(|i| (i % cfg.vocab) as i32).collect();
        backend.run_logits(state.as_ref(), &ids, 1, 6, &mask, None).unwrap();
        let snap = backend.routing_stats(state.as_ref()).unwrap();
        assert_eq!(snap.tokens, 0, "run_logits must not pollute live stats");

        // a served prefill + one decode step record exactly t + 1 tokens,
        // each dispatched to k experts per layer (cap_factor 4.0 → no drops)
        let (cache, _) = backend
            .run_prefill(state.as_ref(), &ids, PrefillOpts::new(&mask))
            .unwrap();
        let mut cache = cache.unwrap();
        backend.run_decode(state.as_ref(), cache.as_mut(), 1, &mask, None).unwrap();
        let snap = backend.routing_stats(state.as_ref()).unwrap();
        assert_eq!(snap.tokens, 7);
        for (l, layer) in snap.counts.iter().enumerate() {
            assert_eq!(
                layer.iter().sum::<u64>(),
                7 * cfg.k as u64,
                "layer {l}: every routed token lands on k slots"
            );
        }
        assert!(snap.dispatch_entropy() > 0.0, "traffic spreads over > 1 expert");
    }
}
