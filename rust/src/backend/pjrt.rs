//! PJRT execution backend: the AOT-lowered HLO artifacts compiled and run
//! through the `xla` bindings (see [`crate::runtime`]).
//!
//! Weights are uploaded **once** per model variant and kept resident as
//! device buffers; only the small per-call inputs (token ids, router mask,
//! remap table) travel per execution (DESIGN.md §"Key design decisions").
//! Offline builds link the vendored `xla` stub, so construction succeeds
//! but every execution reports the missing PJRT plugin — swap real
//! bindings into `rust/Cargo.toml` to make this backend live.
//!
//! Expert-parallel sharding (`HCSMOE_EXPERT_SHARDS`) is a native-backend
//! feature: [`super::from_env`] rejects `shards != 1` here at startup
//! with a descriptive error rather than silently ignoring the knob —
//! on PJRT the equivalent would be device-side partitioning of the
//! lowered MoE layer, tracked in ROADMAP.md alongside the incremental
//! prefill/decode entry points.

use std::sync::{Arc, OnceLock};

use anyhow::{anyhow, ensure, Result};

use crate::config::{Artifacts, ModelCfg};
use crate::runtime::{Executable, Input, Runtime};
use crate::tensor::Tensor;
use crate::weights::Weights;

use super::{downcast_state, Backend, CacheSnapshot, KvCache, ModelState, PrefillOpts, VerifyOut};

/// The PJRT backend: one CPU client plus lazily compiled executables.
pub struct PjrtBackend {
    arts: Artifacts,
    cfg: ModelCfg,
    rt: Arc<Runtime>,
    lm_exe: OnceLock<Executable>,
    calib_exe: OnceLock<Executable>,
}

/// Resident PJRT variant: device buffers (+ a dedicated compact
/// executable when `n_slots < n_exp`, which needs different parameter
/// shapes than the shared `lm_logits` one).
struct PjrtModel {
    bufs: Vec<xla::PjRtBuffer>,
    n_slots: usize,
    compact_exe: Option<Executable>,
}

impl ModelState for PjrtModel {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// `OnceLock::get_or_try_init` is unstable; this free function provides
/// the same fallible memoisation (a lost init race recomputes, then
/// discards).
fn exe_cached(
    cell: &OnceLock<Executable>,
    load: impl FnOnce() -> Result<Executable>,
) -> Result<&Executable> {
    if let Some(exe) = cell.get() {
        return Ok(exe);
    }
    let exe = load()?;
    Ok(cell.get_or_init(|| exe))
}

impl PjrtBackend {
    /// Bind a PJRT CPU client to one model's artifact set.
    pub fn new(arts: Artifacts, cfg: ModelCfg) -> Result<Self> {
        let rt = Runtime::cpu()?;
        Ok(Self { arts, cfg, rt, lm_exe: OnceLock::new(), calib_exe: OnceLock::new() })
    }

    fn lm_exe(&self) -> Result<&Executable> {
        exe_cached(&self.lm_exe, || {
            self.rt.load_hlo(self.arts.lm_logits_hlo(&self.cfg.name))
        })
    }

    fn calib_exe(&self) -> Result<&Executable> {
        exe_cached(&self.calib_exe, || {
            self.rt.load_hlo(self.arts.calib_hlo(&self.cfg.name))
        })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn load_model(&self, weights: &Weights, n_slots: usize) -> Result<Box<dyn ModelState>> {
        ensure!(
            weights.n_experts()? == n_slots,
            "weight set has {} expert slots, expected {n_slots}",
            weights.n_experts()?
        );
        let compact_exe = if n_slots == self.cfg.n_exp {
            None
        } else {
            Some(
                self.rt
                    .load_hlo(self.arts.lm_logits_compact_hlo(&self.cfg.name, n_slots))?,
            )
        };
        let bufs = weights
            .ordered()
            .iter()
            .map(|t| self.rt.upload_f32(t))
            .collect::<Result<Vec<_>>>()?;
        Ok(Box::new(PjrtModel { bufs, n_slots, compact_exe }))
    }

    fn run_logits(
        &self,
        state: &dyn ModelState,
        ids: &[i32],
        b: usize,
        t: usize,
        mask: &[f32],
        remap: Option<&[i32]>,
    ) -> Result<Tensor> {
        let m: &PjrtModel = downcast_state(state, self.name())?;
        ensure!(ids.len() == b * t, "ids must be exactly [{b}, {t}]");
        let mask_t = Tensor::new(vec![self.cfg.n_layer, self.cfg.n_exp], mask.to_vec())?;
        let mut inputs = vec![Input::I32(ids.to_vec(), vec![b, t]), Input::F32(mask_t)];
        if let Some(rm) = remap {
            inputs.push(Input::I32(
                rm.to_vec(),
                vec![self.cfg.n_layer, self.cfg.n_exp],
            ));
        }
        let exe = match &m.compact_exe {
            Some(exe) => exe,
            None => self.lm_exe()?,
        };
        let outs = exe.run_with(&m.bufs, &inputs)?;
        ensure!(outs.len() == 1, "lm_logits returns a 1-tuple");
        Ok(outs.into_iter().next().unwrap())
    }

    fn run_calib(
        &self,
        state: &dyn ModelState,
        ids: &[i32],
        b: usize,
        t: usize,
        _t_sub: usize,
        _t_act: usize,
    ) -> Result<Vec<Tensor>> {
        // t_sub/t_act are baked into the lowered calib executable; the
        // caller's values come from the same manifest the artifacts were
        // generated with.
        let m: &PjrtModel = downcast_state(state, self.name())?;
        ensure!(ids.len() == b * t, "calib ids must be exactly [{b}, {t}]");
        ensure!(m.n_slots == self.cfg.n_exp, "calibration needs the full layout");
        self.calib_exe()?
            .run_with(&m.bufs, &[Input::I32(ids.to_vec(), vec![b, t])])
    }

    fn run_prefill(
        &self,
        _state: &dyn ModelState,
        _ids: &[i32],
        _opts: PrefillOpts<'_>,
    ) -> Result<(Option<Box<dyn KvCache>>, Vec<f32>)> {
        // The AOT artifact set lowers only the fixed-shape batched entry
        // points (lm_logits_* / calib_*); no incremental prefill/decode
        // executables exist yet. Lowering them (a [1, t] prefill emitting
        // K/V outputs + a [1, 1] decode taking them as parameters — the
        // paged cache mode and chunked resume additionally need block-table
        // gather/scatter parameters) is the tracked follow-up — until
        // then, generation runs on the native backend (the default).
        Err(anyhow!(
            "the pjrt backend has no incremental prefill/decode HLO entry points; \
             run generation on the native backend (unset HCSMOE_BACKEND or set it \
             to \"native\")"
        ))
    }

    fn run_decode(
        &self,
        _state: &dyn ModelState,
        _cache: &mut dyn KvCache,
        _token: i32,
        _mask: &[f32],
        _remap: Option<&[i32]>,
    ) -> Result<Vec<f32>> {
        Err(anyhow!(
            "the pjrt backend has no incremental prefill/decode HLO entry points; \
             run generation on the native backend (unset HCSMOE_BACKEND or set it \
             to \"native\")"
        ))
    }

    fn run_decode_batch(
        &self,
        _state: &dyn ModelState,
        _caches: &mut [&mut dyn KvCache],
        _tokens: &[i32],
        _mask: &[f32],
        _remap: Option<&[i32]>,
    ) -> Result<Vec<Vec<f32>>> {
        // Batched decode rides the same missing incremental entry points as
        // run_prefill/run_decode: lowering a [B, 1] decode executable that
        // takes the cached K/V as parameters is part of the same tracked
        // follow-up (see SERVING.md, "PJRT status").
        Err(anyhow!(
            "the pjrt backend has no incremental prefill/decode HLO entry points; \
             run generation on the native backend (unset HCSMOE_BACKEND or set it \
             to \"native\")"
        ))
    }

    fn run_verify(
        &self,
        _state: &dyn ModelState,
        _caches: &mut [&mut dyn KvCache],
        _tokens: &[&[i32]],
        _mask: &[f32],
        _remap: Option<&[i32]>,
    ) -> Result<Vec<VerifyOut>> {
        // Speculative verify is a ragged [sum(k_i), 1] decode over the same
        // missing incremental entry points (see run_prefill above).
        Err(anyhow!(
            "the pjrt backend has no incremental prefill/decode HLO entry points; \
             run generation on the native backend (unset HCSMOE_BACKEND or set it \
             to \"native\")"
        ))
    }

    fn snapshot_cache(&self, _cache: &dyn KvCache) -> Result<CacheSnapshot> {
        Err(anyhow!(
            "the pjrt backend has no incremental caches to snapshot; \
             run generation on the native backend (unset HCSMOE_BACKEND or set it \
             to \"native\")"
        ))
    }

    fn rollback_cache(&self, _cache: &mut dyn KvCache, _snap: &CacheSnapshot) -> Result<()> {
        Err(anyhow!(
            "the pjrt backend has no incremental caches to roll back; \
             run generation on the native backend (unset HCSMOE_BACKEND or set it \
             to \"native\")"
        ))
    }

    fn routing_stats(&self, _state: &dyn ModelState) -> Option<super::RoutingSnapshot> {
        // Routing decisions happen inside the lowered HLO on this path;
        // surfacing them would need a dedicated counts output on the
        // executables (same follow-up as the incremental entry points).
        // `None` makes the serving layer's adaptive path a no-op here.
        None
    }
}
