//! Experiment/model configuration: the `.cfg` and `manifest.txt` artifacts
//! written by `python/compile/aot.py`, plus path resolution for everything
//! under `artifacts/`. Runtime environment knobs (`HCSMOE_BACKEND`,
//! `HCSMOE_KV_BUDGET_MB`, `HCSMOE_PREFILL_CHUNK`) parse in [`env`].

pub mod env;

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::util::KvFile;

/// Mirror of `python/compile/model.py::ModelCfg`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    /// Model family name (`qwensim` / `mixsim` / `dssim`).
    pub name: String,
    /// Transformer layer count.
    pub n_layer: usize,
    /// Hidden size (d_h in the paper).
    pub d: usize,
    /// Expert FFN size (d_m).
    pub m: usize,
    /// Experts per layer (n).
    pub n_exp: usize,
    /// Top-k routing fan-out.
    pub k: usize,
    /// Attention head count (must divide `d`).
    pub heads: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Maximum sequence length (learned positions).
    pub t_max: usize,
    /// DeepSeek-style always-on shared expert (`dssim`).
    pub shared: bool,
    /// Shared-expert FFN size.
    pub m_shared: usize,
    /// Expert capacity factor for dispatch.
    pub cap_factor: f64,
    /// Token-block size the capacity is rounded up to.
    pub block_c: usize,
}

impl ModelCfg {
    /// Parse a `.cfg` artifact.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let kv = KvFile::load(path)?;
        Ok(Self {
            name: kv.get("name")?.to_string(),
            n_layer: kv.usize("n_layer")?,
            d: kv.usize("d")?,
            m: kv.usize("m")?,
            n_exp: kv.usize("n_exp")?,
            k: kv.usize("k")?,
            heads: kv.usize("heads")?,
            vocab: kv.usize("vocab")?,
            t_max: kv.usize("t_max")?,
            shared: kv.bool("shared")?,
            m_shared: kv.usize("m_shared")?,
            cap_factor: kv.f64("cap_factor")?,
            block_c: kv.usize("block_c")?,
        })
    }

    /// Parameters of one expert (Eq. 2: three matrices).
    pub fn expert_params(&self) -> usize {
        3 * self.d * self.m
    }

    /// Total parameter count with `r` experts per layer (Table 20 column).
    pub fn total_params(&self, r: usize) -> usize {
        let embed = self.vocab * self.d + self.t_max * self.d + self.d;
        let mut per_layer = 4 * self.d * self.d + 2 * self.d + self.d * self.n_exp;
        per_layer += r * self.expert_params();
        if self.shared {
            per_layer += 3 * self.d * self.m_shared;
        }
        embed + self.n_layer * per_layer
    }

    /// Analytic forward GFLOPs per token with `r` experts retained, using
    /// the paper's dense-equivalent accounting (Table 20 scales GFLOPs with
    /// the retained expert count). Counts multiply-adds as 2 flops.
    pub fn flops_per_token(&self, r: usize) -> f64 {
        let attn = 4.0 * 2.0 * (self.d * self.d) as f64;
        // dense-equivalent expert compute across the r retained experts
        let moe = r as f64 * 2.0 * (3 * self.d * self.m) as f64;
        let shared = if self.shared { 2.0 * (3 * self.d * self.m_shared) as f64 } else { 0.0 };
        let head = 2.0 * (self.d * self.vocab) as f64;
        self.n_layer as f64 * (attn + moe + shared) + head
    }

    /// Resident bytes of one sequence's attention KV cache at length `t`:
    /// one K and one V row of `d` f32 values per layer per token —
    /// `2 · n_layer · t · d · 4` bytes. Independent of `heads` (the heads
    /// partition `d`, they do not multiply it) and of the expert count
    /// (expert weights are model state, not sequence state). This is the
    /// per-sequence memory cost of serving decode traffic; see
    /// `SERVING.md` §"KV-cache memory accounting".
    pub fn kv_cache_bytes(&self, t: usize) -> usize {
        2 * self.n_layer * t * self.d * std::mem::size_of::<f32>()
    }

    /// Bytes of one paged KV block holding `block_tokens` positions: all
    /// layers' K and V rows for those positions
    /// (`2 · n_layer · block_tokens · d · 4` B — i.e.
    /// [`Self::kv_cache_bytes`] at `t = block_tokens`). The unit of the
    /// [`crate::kvpool`] budget arithmetic; see `SERVING.md` §"KV memory
    /// model".
    pub fn kv_block_bytes(&self, block_tokens: usize) -> usize {
        self.kv_cache_bytes(block_tokens)
    }

    /// Per-expert capacity for `n_tokens`, mirroring the Python side.
    pub fn capacity(&self, n_tokens: usize, n_exp: usize) -> usize {
        let c = (self.k as f64 * n_tokens as f64 * self.cap_factor / n_exp as f64).ceil();
        let b = self.block_c as f64;
        ((c / b).ceil() * b) as usize
    }
}

/// Global artifact geometry (manifest.txt).
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Scoring batch rows.
    pub eval_b: usize,
    /// Scoring sequence length.
    pub eval_t: usize,
    /// Calibration batch rows.
    pub calib_b: usize,
    /// Calibration sequence length.
    pub calib_t: usize,
    /// Subsampled-profile token count captured by the calibration pass.
    pub t_sub: usize,
    /// Subsampled-activation token count (<= `t_sub`).
    pub t_act: usize,
    /// Items per benchmark task.
    pub n_items: usize,
    /// Model family names shipped in this artifact set.
    pub models: Vec<String>,
    /// Benchmark task names shipped in this artifact set.
    pub tasks: Vec<String>,
    /// Per-model expert-count reduction schedules.
    pub reductions: std::collections::BTreeMap<String, Vec<usize>>,
}

impl Manifest {
    /// Parse `manifest.txt`.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let kv = KvFile::load(path)?;
        let models = kv.list("models")?;
        let mut reductions = std::collections::BTreeMap::new();
        for m in &models {
            reductions.insert(m.clone(), kv.usize_list(&format!("reductions_{m}"))?);
        }
        Ok(Self {
            eval_b: kv.usize("eval_b")?,
            eval_t: kv.usize("eval_t")?,
            calib_b: kv.usize("calib_b")?,
            calib_t: kv.usize("calib_t")?,
            t_sub: kv.usize("t_sub")?,
            t_act: kv.usize("t_act")?,
            n_items: kv.usize("n_items")?,
            models,
            tasks: kv.list("tasks")?,
            reductions,
        })
    }

    /// Tokens per calibration batch (`calib_b * calib_t`).
    pub fn calib_tokens(&self) -> usize {
        self.calib_b * self.calib_t
    }
}

/// Path helper rooted at the artifacts directory.
#[derive(Debug, Clone)]
pub struct Artifacts {
    /// Artifact directory root.
    pub root: PathBuf,
}

impl Artifacts {
    /// Artifacts rooted at an explicit directory.
    pub fn new<P: AsRef<Path>>(root: P) -> Self {
        Self { root: root.as_ref().to_path_buf() }
    }

    /// Default location: `$HCSMOE_ARTIFACTS` or `./artifacts`.
    pub fn discover() -> Self {
        let root = std::env::var("HCSMOE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::new(root)
    }

    /// Load `manifest.txt`.
    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(self.root.join("manifest.txt"))
    }

    /// Load `<model>.cfg`.
    pub fn model_cfg(&self, model: &str) -> Result<ModelCfg> {
        ModelCfg::load(self.root.join(format!("{model}.cfg")))
    }

    /// Path of the `<model>.hcwt` checkpoint.
    pub fn weights_path(&self, model: &str) -> PathBuf {
        self.root.join(format!("{model}.hcwt"))
    }

    /// Path of the scoring-forward HLO text artifact.
    pub fn lm_logits_hlo(&self, model: &str) -> PathBuf {
        self.root.join(format!("hlo/lm_logits_{model}.hlo.txt"))
    }

    /// Path of the compact r-expert scoring HLO artifact.
    pub fn lm_logits_compact_hlo(&self, model: &str, r: usize) -> PathBuf {
        self.root.join(format!("hlo/lm_logits_{model}_r{r}.hlo.txt"))
    }

    /// Path of the calibration-pass HLO artifact.
    pub fn calib_hlo(&self, model: &str) -> PathBuf {
        self.root.join(format!("hlo/calib_{model}.hlo.txt"))
    }

    /// Path of a benchmark task's HCEV file.
    pub fn benchmark(&self, task: &str) -> PathBuf {
        self.root.join(format!("eval/{task}.bin"))
    }

    /// Path of a calibration domain's HCTS token stream.
    pub fn calib_tokens_path(&self, domain: &str) -> PathBuf {
        self.root.join(format!("calib/{domain}.bin"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_cfg() -> ModelCfg {
        ModelCfg {
            name: "qwensim".into(),
            n_layer: 4,
            d: 96,
            m: 96,
            n_exp: 16,
            k: 2,
            heads: 4,
            vocab: 448,
            t_max: 256,
            shared: false,
            m_shared: 192,
            cap_factor: 1.5,
            block_c: 32,
        }
    }

    #[test]
    fn params_monotone_in_r() {
        let c = demo_cfg();
        assert!(c.total_params(16) > c.total_params(8));
        assert!(c.total_params(8) > c.total_params(4));
        // expert params dominate: halving experts saves close to half the
        // expert block
        let full = c.total_params(16);
        let half = c.total_params(8);
        let expert_block = 16 * c.expert_params() * c.n_layer;
        assert!((full - half) * 2 == expert_block);
    }

    #[test]
    fn capacity_is_block_aligned() {
        let c = demo_cfg();
        let cap = c.capacity(1024, 16);
        assert_eq!(cap % c.block_c, 0);
        assert!(cap * 16 >= 2 * 1024); // fits all k*T slots at factor >= 1
    }

    #[test]
    fn parse_cfg_text() {
        let text = "name = qwensim\nn_layer = 4\nd = 96\nm = 96\nn_exp = 16\nk = 2\nheads = 4\nvocab = 448\nt_max = 256\nshared = 0\nm_shared = 192\ncap_factor = 1.5\nblock_c = 32\n";
        let tmp = std::env::temp_dir().join("hcsmoe_cfg_test.cfg");
        std::fs::write(&tmp, text).unwrap();
        let cfg = ModelCfg::load(&tmp).unwrap();
        assert_eq!(cfg, demo_cfg());
        std::fs::remove_file(tmp).ok();
    }
}
