//! Runtime environment knobs, parsed in one place with startup-time
//! validation.
//!
//! Every `HCSMOE_*` variable that changes runtime behavior resolves
//! through this module so a *set but malformed* value is a startup error
//! with a uniform message style — silently falling back to a default
//! would run a different configuration than the operator asked for:
//!
//! | variable              | meaning                                   | default      |
//! |-----------------------|-------------------------------------------|--------------|
//! | `HCSMOE_BACKEND`      | execution backend (`native` \| `pjrt`)    | `native`     |
//! | `HCSMOE_KV_BUDGET_MB` | paged KV-cache pool budget, whole MiB     | 64           |
//! | `HCSMOE_PREFILL_CHUNK`| prompt tokens per prefill chunk (>= 1)    | unchunked    |
//!
//! The resolvers below each take the corresponding `ServeSpec` field (or
//! nothing, for process-wide knobs) and apply the precedence *explicit
//! spec value → environment → default*. Pure `parse_*` helpers carry the
//! validation so it is unit-testable without mutating the process
//! environment (env mutation is racy across test threads).

use anyhow::{anyhow, Result};

/// Environment variable selecting the execution backend
/// (`native` | `pjrt`, default `native`).
pub const BACKEND_ENV: &str = "HCSMOE_BACKEND";

/// Environment variable for the paged KV-cache pool budget in MiB.
pub const KV_BUDGET_ENV: &str = "HCSMOE_KV_BUDGET_MB";

/// Default KV pool budget when neither the spec nor [`KV_BUDGET_ENV`]
/// says otherwise (MiB).
pub const DEFAULT_KV_BUDGET_MB: usize = 64;

/// Environment variable bounding how many prompt tokens the serving
/// scheduler prefills between consecutive decode steps (chunked prefill;
/// see `SERVING.md` §"Scheduler"). Unset = whole-prompt prefills.
pub const PREFILL_CHUNK_ENV: &str = "HCSMOE_PREFILL_CHUNK";

/// Which execution backend to construct (see [`crate::backend::from_env`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// The pure-Rust CPU interpreter (default).
    Native,
    /// The PJRT/HLO path.
    Pjrt,
}

/// Resolve [`BACKEND_ENV`] (default: [`BackendKind::Native`]).
pub fn backend_kind() -> Result<BackendKind> {
    match std::env::var(BACKEND_ENV) {
        Ok(v) => parse_backend(&v),
        Err(_) => Ok(BackendKind::Native),
    }
}

fn parse_backend(v: &str) -> Result<BackendKind> {
    match v {
        "native" | "" => Ok(BackendKind::Native),
        "pjrt" => Ok(BackendKind::Pjrt),
        other => Err(anyhow!(
            "unknown {BACKEND_ENV}={other:?} (expected \"native\" or \"pjrt\")"
        )),
    }
}

/// Resolve the KV pool budget in **bytes**: the explicit spec value when
/// given, else [`KV_BUDGET_ENV`], else the
/// [`DEFAULT_KV_BUDGET_MB`]-MiB default.
pub fn kv_budget_bytes(explicit: Option<usize>) -> Result<usize> {
    if let Some(bytes) = explicit {
        return Ok(bytes);
    }
    match std::env::var(KV_BUDGET_ENV) {
        Ok(v) => Ok(parse_kv_budget_mb(&v)? * 1024 * 1024),
        Err(_) => Ok(DEFAULT_KV_BUDGET_MB * 1024 * 1024),
    }
}

fn parse_kv_budget_mb(v: &str) -> Result<usize> {
    v.trim()
        .parse()
        .map_err(|_| anyhow!("{KV_BUDGET_ENV}={v:?} is not a whole MiB count (e.g. 64)"))
}

/// Resolve the prefill chunk size in tokens: the explicit spec value when
/// given, else [`PREFILL_CHUNK_ENV`], else `None` (whole-prompt
/// prefills). `Some(0)` from the spec is rejected like a malformed env
/// value — a zero-token chunk could never finish a prefill.
pub fn prefill_chunk(explicit: Option<usize>) -> Result<Option<usize>> {
    if let Some(chunk) = explicit {
        if chunk == 0 {
            return Err(anyhow!(
                "prefill_chunk=0 is not a positive token count (e.g. 32)"
            ));
        }
        return Ok(Some(chunk));
    }
    match std::env::var(PREFILL_CHUNK_ENV) {
        Ok(v) => Ok(Some(parse_prefill_chunk(&v)?)),
        Err(_) => Ok(None),
    }
}

fn parse_prefill_chunk(v: &str) -> Result<usize> {
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err(anyhow!(
            "{PREFILL_CHUNK_ENV}={v:?} is not a positive token count (e.g. 32)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parses_known_names_and_rejects_others() {
        assert_eq!(parse_backend("native").unwrap(), BackendKind::Native);
        assert_eq!(parse_backend("").unwrap(), BackendKind::Native);
        assert_eq!(parse_backend("pjrt").unwrap(), BackendKind::Pjrt);
        let err = parse_backend("cuda").unwrap_err().to_string();
        assert!(err.contains("HCSMOE_BACKEND"), "{err}");
        assert!(err.contains("expected"), "{err}");
    }

    #[test]
    fn kv_budget_parses_mib_counts() {
        assert_eq!(parse_kv_budget_mb("64").unwrap(), 64);
        assert_eq!(parse_kv_budget_mb(" 8 ").unwrap(), 8);
        let err = parse_kv_budget_mb("lots").unwrap_err().to_string();
        assert!(err.contains("HCSMOE_KV_BUDGET_MB"), "{err}");
        // explicit spec bytes win without consulting the environment
        assert_eq!(kv_budget_bytes(Some(12345)).unwrap(), 12345);
    }

    #[test]
    fn prefill_chunk_requires_a_positive_count() {
        assert_eq!(parse_prefill_chunk("32").unwrap(), 32);
        assert_eq!(parse_prefill_chunk("1").unwrap(), 1);
        for bad in ["0", "-4", "many", ""] {
            let err = parse_prefill_chunk(bad).unwrap_err().to_string();
            assert!(err.contains("HCSMOE_PREFILL_CHUNK"), "{err}");
        }
        // explicit spec values win, and zero is rejected at startup
        assert_eq!(prefill_chunk(Some(16)).unwrap(), Some(16));
        assert!(prefill_chunk(Some(0)).is_err());
    }
}
