//! Runtime environment knobs, parsed in one place with startup-time
//! validation.
//!
//! Every `HCSMOE_*` variable that changes runtime behavior resolves
//! through this module so a *set but malformed* value is a startup error
//! with a uniform message style — silently falling back to a default
//! would run a different configuration than the operator asked for:
//!
//! | variable                  | meaning                                   | default      |
//! |---------------------------|-------------------------------------------|--------------|
//! | `HCSMOE_BACKEND`          | execution backend (`native` \| `pjrt`)    | `native`     |
//! | `HCSMOE_KV_BUDGET_MB`     | paged KV-cache pool budget, whole MiB     | 64           |
//! | `HCSMOE_PREFILL_CHUNK`    | prompt tokens per prefill chunk (>= 1)    | unchunked    |
//! | `HCSMOE_ADAPT_WINDOW`     | routed tokens per adaptive-recompression window (>= 1) | 4096 |
//! | `HCSMOE_ADAPT_MIN_TOKENS` | total routed tokens before the first recompression | 0 |
//!
//! The resolvers below each take the corresponding `ServeSpec` field (or
//! nothing, for process-wide knobs) and apply the precedence *explicit
//! spec value → environment → default*. Pure `parse_*` helpers carry the
//! validation so it is unit-testable without mutating the process
//! environment (env mutation is racy across test threads).

use anyhow::{anyhow, Result};

/// Environment variable selecting the execution backend
/// (`native` | `pjrt`, default `native`).
pub const BACKEND_ENV: &str = "HCSMOE_BACKEND";

/// Environment variable for the paged KV-cache pool budget in MiB.
pub const KV_BUDGET_ENV: &str = "HCSMOE_KV_BUDGET_MB";

/// Default KV pool budget when neither the spec nor [`KV_BUDGET_ENV`]
/// says otherwise (MiB).
pub const DEFAULT_KV_BUDGET_MB: usize = 64;

/// Environment variable bounding how many prompt tokens the serving
/// scheduler prefills between consecutive decode steps (chunked prefill;
/// see `SERVING.md` §"Scheduler"). Unset = whole-prompt prefills.
pub const PREFILL_CHUNK_ENV: &str = "HCSMOE_PREFILL_CHUNK";

/// Environment variable setting how many routed tokens the adaptive
/// server observes per recompression window (see `SERVING.md`
/// §"Adaptive compression & hot swap"). A background recompression is
/// considered once the live [`crate::backend::RoutingSnapshot`] has
/// accumulated this many tokens since the last swap.
pub const ADAPT_WINDOW_ENV: &str = "HCSMOE_ADAPT_WINDOW";

/// Default adaptive-recompression window when neither the spec nor
/// [`ADAPT_WINDOW_ENV`] says otherwise (routed tokens).
pub const DEFAULT_ADAPT_WINDOW: u64 = 4096;

/// Environment variable setting the total routed-token floor before the
/// FIRST adaptive recompression may trigger — a warm-up guard so a few
/// unrepresentative early requests cannot specialize the model.
pub const ADAPT_MIN_TOKENS_ENV: &str = "HCSMOE_ADAPT_MIN_TOKENS";

/// Which execution backend to construct (see [`crate::backend::from_env`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// The pure-Rust CPU interpreter (default).
    Native,
    /// The PJRT/HLO path.
    Pjrt,
}

/// Resolve [`BACKEND_ENV`] (default: [`BackendKind::Native`]).
pub fn backend_kind() -> Result<BackendKind> {
    match std::env::var(BACKEND_ENV) {
        Ok(v) => parse_backend(&v),
        Err(_) => Ok(BackendKind::Native),
    }
}

fn parse_backend(v: &str) -> Result<BackendKind> {
    match v {
        "native" | "" => Ok(BackendKind::Native),
        "pjrt" => Ok(BackendKind::Pjrt),
        other => Err(anyhow!(
            "unknown {BACKEND_ENV}={other:?} (expected \"native\" or \"pjrt\")"
        )),
    }
}

/// Resolve the KV pool budget in **bytes**: the explicit spec value when
/// given, else [`KV_BUDGET_ENV`], else the
/// [`DEFAULT_KV_BUDGET_MB`]-MiB default.
pub fn kv_budget_bytes(explicit: Option<usize>) -> Result<usize> {
    if let Some(bytes) = explicit {
        return Ok(bytes);
    }
    match std::env::var(KV_BUDGET_ENV) {
        Ok(v) => Ok(parse_kv_budget_mb(&v)? * 1024 * 1024),
        Err(_) => Ok(DEFAULT_KV_BUDGET_MB * 1024 * 1024),
    }
}

fn parse_kv_budget_mb(v: &str) -> Result<usize> {
    v.trim()
        .parse()
        .map_err(|_| anyhow!("{KV_BUDGET_ENV}={v:?} is not a whole MiB count (e.g. 64)"))
}

/// Resolve the prefill chunk size in tokens: the explicit spec value when
/// given, else [`PREFILL_CHUNK_ENV`], else `None` (whole-prompt
/// prefills). `Some(0)` from the spec is rejected like a malformed env
/// value — a zero-token chunk could never finish a prefill.
pub fn prefill_chunk(explicit: Option<usize>) -> Result<Option<usize>> {
    if let Some(chunk) = explicit {
        if chunk == 0 {
            return Err(anyhow!(
                "prefill_chunk=0 is not a positive token count (e.g. 32)"
            ));
        }
        return Ok(Some(chunk));
    }
    match std::env::var(PREFILL_CHUNK_ENV) {
        Ok(v) => Ok(Some(parse_prefill_chunk(&v)?)),
        Err(_) => Ok(None),
    }
}

fn parse_prefill_chunk(v: &str) -> Result<usize> {
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err(anyhow!(
            "{PREFILL_CHUNK_ENV}={v:?} is not a positive token count (e.g. 32)"
        )),
    }
}

/// Resolve the adaptive-recompression window in routed tokens: the
/// explicit spec value when given, else [`ADAPT_WINDOW_ENV`], else
/// [`DEFAULT_ADAPT_WINDOW`]. `Some(0)` from the spec is rejected like a
/// malformed env value — a zero-token window would recompress on every
/// executor iteration.
pub fn adapt_window(explicit: Option<u64>) -> Result<u64> {
    if let Some(w) = explicit {
        if w == 0 {
            return Err(anyhow!(
                "adapt window=0 is not a positive token count (e.g. 4096)"
            ));
        }
        return Ok(w);
    }
    match std::env::var(ADAPT_WINDOW_ENV) {
        Ok(v) => parse_adapt_window(&v),
        Err(_) => Ok(DEFAULT_ADAPT_WINDOW),
    }
}

fn parse_adapt_window(v: &str) -> Result<u64> {
    match v.trim().parse::<u64>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err(anyhow!(
            "{ADAPT_WINDOW_ENV}={v:?} is not a positive token count (e.g. 4096)"
        )),
    }
}

/// Resolve the total routed-token floor before the first adaptive
/// recompression: the explicit spec value when given, else
/// [`ADAPT_MIN_TOKENS_ENV`], else `0` (no warm-up floor beyond the
/// window itself). Zero is a legal value — unlike the window, a zero
/// floor is simply "no extra guard".
pub fn adapt_min_tokens(explicit: Option<u64>) -> Result<u64> {
    if let Some(n) = explicit {
        return Ok(n);
    }
    match std::env::var(ADAPT_MIN_TOKENS_ENV) {
        Ok(v) => parse_adapt_min_tokens(&v),
        Err(_) => Ok(0),
    }
}

fn parse_adapt_min_tokens(v: &str) -> Result<u64> {
    v.trim().parse::<u64>().map_err(|_| {
        anyhow!("{ADAPT_MIN_TOKENS_ENV}={v:?} is not a token count (e.g. 8192)")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parses_known_names_and_rejects_others() {
        assert_eq!(parse_backend("native").unwrap(), BackendKind::Native);
        assert_eq!(parse_backend("").unwrap(), BackendKind::Native);
        assert_eq!(parse_backend("pjrt").unwrap(), BackendKind::Pjrt);
        let err = parse_backend("cuda").unwrap_err().to_string();
        assert!(err.contains("HCSMOE_BACKEND"), "{err}");
        assert!(err.contains("expected"), "{err}");
    }

    #[test]
    fn kv_budget_parses_mib_counts() {
        assert_eq!(parse_kv_budget_mb("64").unwrap(), 64);
        assert_eq!(parse_kv_budget_mb(" 8 ").unwrap(), 8);
        let err = parse_kv_budget_mb("lots").unwrap_err().to_string();
        assert!(err.contains("HCSMOE_KV_BUDGET_MB"), "{err}");
        // explicit spec bytes win without consulting the environment
        assert_eq!(kv_budget_bytes(Some(12345)).unwrap(), 12345);
    }

    #[test]
    fn prefill_chunk_requires_a_positive_count() {
        assert_eq!(parse_prefill_chunk("32").unwrap(), 32);
        assert_eq!(parse_prefill_chunk("1").unwrap(), 1);
        for bad in ["0", "-4", "many", ""] {
            let err = parse_prefill_chunk(bad).unwrap_err().to_string();
            assert!(err.contains("HCSMOE_PREFILL_CHUNK"), "{err}");
        }
        // explicit spec values win, and zero is rejected at startup
        assert_eq!(prefill_chunk(Some(16)).unwrap(), Some(16));
        assert!(prefill_chunk(Some(0)).is_err());
    }

    #[test]
    fn adapt_window_requires_a_positive_count() {
        assert_eq!(parse_adapt_window("4096").unwrap(), 4096);
        assert_eq!(parse_adapt_window(" 1 ").unwrap(), 1);
        for bad in ["0", "-4", "soon", ""] {
            let err = parse_adapt_window(bad).unwrap_err().to_string();
            assert!(err.contains("HCSMOE_ADAPT_WINDOW"), "{err}");
        }
        // explicit spec values win, and zero is rejected at startup
        assert_eq!(adapt_window(Some(64)).unwrap(), 64);
        assert!(adapt_window(Some(0)).is_err());
    }

    #[test]
    fn adapt_min_tokens_parses_counts_and_allows_zero() {
        assert_eq!(parse_adapt_min_tokens("8192").unwrap(), 8192);
        assert_eq!(parse_adapt_min_tokens("0").unwrap(), 0);
        for bad in ["-1", "never", ""] {
            let err = parse_adapt_min_tokens(bad).unwrap_err().to_string();
            assert!(err.contains("HCSMOE_ADAPT_MIN_TOKENS"), "{err}");
        }
        // explicit spec values win without consulting the environment
        assert_eq!(adapt_min_tokens(Some(7)).unwrap(), 7);
        assert_eq!(adapt_min_tokens(Some(0)).unwrap(), 0);
    }
}
