//! Runtime environment knobs, parsed in one place with startup-time
//! validation.
//!
//! Every `HCSMOE_*` variable that changes runtime behavior resolves
//! through this module so a *set but malformed* value is a startup error
//! with a uniform message style — silently falling back to a default
//! would run a different configuration than the operator asked for:
//!
//! | variable                  | meaning                                   | default      |
//! |---------------------------|-------------------------------------------|--------------|
//! | `HCSMOE_BACKEND`          | execution backend (`native` \| `pjrt`)    | `native`     |
//! | `HCSMOE_KV_BUDGET_MB`     | paged KV-cache pool budget, whole MiB     | 64           |
//! | `HCSMOE_PREFILL_CHUNK`    | prompt tokens per prefill chunk (>= 1)    | unchunked    |
//! | `HCSMOE_ADAPT_WINDOW`     | routed tokens per adaptive-recompression window (>= 1) | 4096 |
//! | `HCSMOE_ADAPT_MIN_TOKENS` | total routed tokens before the first recompression | 0 |
//! | `HCSMOE_REPLICAS`         | serving executor replicas behind the dispatcher (>= 1) | 1 |
//! | `HCSMOE_EXPERT_SHARDS`    | expert-parallel shards per MoE layer (>= 1)            | 1 |
//! | `HCSMOE_HTTP_ADDR`        | HTTP front-end listen address (`host:port`)            | unset |
//!
//! The resolvers below each take the corresponding `ServeSpec` field (or
//! nothing, for process-wide knobs) and apply the precedence *explicit
//! spec value → environment → default*. Pure `parse_*` helpers carry the
//! validation so it is unit-testable without mutating the process
//! environment (env mutation is racy across test threads).

use anyhow::{anyhow, Result};

/// Environment variable selecting the execution backend
/// (`native` | `pjrt`, default `native`).
pub const BACKEND_ENV: &str = "HCSMOE_BACKEND";

/// Environment variable for the paged KV-cache pool budget in MiB.
pub const KV_BUDGET_ENV: &str = "HCSMOE_KV_BUDGET_MB";

/// Default KV pool budget when neither the spec nor [`KV_BUDGET_ENV`]
/// says otherwise (MiB).
pub const DEFAULT_KV_BUDGET_MB: usize = 64;

/// Environment variable bounding how many prompt tokens the serving
/// scheduler prefills between consecutive decode steps (chunked prefill;
/// see `SERVING.md` §"Scheduler"). Unset = whole-prompt prefills.
pub const PREFILL_CHUNK_ENV: &str = "HCSMOE_PREFILL_CHUNK";

/// Environment variable setting how many routed tokens the adaptive
/// server observes per recompression window (see `SERVING.md`
/// §"Adaptive compression & hot swap"). A background recompression is
/// considered once the live [`crate::backend::RoutingSnapshot`] has
/// accumulated this many tokens since the last swap.
pub const ADAPT_WINDOW_ENV: &str = "HCSMOE_ADAPT_WINDOW";

/// Default adaptive-recompression window when neither the spec nor
/// [`ADAPT_WINDOW_ENV`] says otherwise (routed tokens).
pub const DEFAULT_ADAPT_WINDOW: u64 = 4096;

/// Environment variable setting the total routed-token floor before the
/// FIRST adaptive recompression may trigger — a warm-up guard so a few
/// unrepresentative early requests cannot specialize the model.
pub const ADAPT_MIN_TOKENS_ENV: &str = "HCSMOE_ADAPT_MIN_TOKENS";

/// Environment variable setting how many serving executor replicas the
/// dispatcher places requests across (see `SERVING.md` §"Execution
/// topology"). Each replica owns its own `ModelContext`, variant pins,
/// and KV pool.
pub const REPLICAS_ENV: &str = "HCSMOE_REPLICAS";

/// Environment variable setting how many expert-parallel shards each
/// MoE layer's routed experts are partitioned into inside the native
/// backend. `1` (the default) is the serial per-expert sweep; higher
/// values compute expert blocks concurrently while keeping the gated
/// combine in expert-ascending order, so outputs stay bit-identical.
pub const EXPERT_SHARDS_ENV: &str = "HCSMOE_EXPERT_SHARDS";

/// Environment variable setting the HTTP/1.1 front-end listen address
/// (`host:port`, e.g. `127.0.0.1:8089`). Unset = no HTTP front end.
pub const HTTP_ADDR_ENV: &str = "HCSMOE_HTTP_ADDR";

/// Which execution backend to construct (see [`crate::backend::from_env`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// The pure-Rust CPU interpreter (default).
    Native,
    /// The PJRT/HLO path.
    Pjrt,
}

/// Resolve [`BACKEND_ENV`] (default: [`BackendKind::Native`]).
pub fn backend_kind() -> Result<BackendKind> {
    match std::env::var(BACKEND_ENV) {
        Ok(v) => parse_backend(&v),
        Err(_) => Ok(BackendKind::Native),
    }
}

fn parse_backend(v: &str) -> Result<BackendKind> {
    match v {
        "native" | "" => Ok(BackendKind::Native),
        "pjrt" => Ok(BackendKind::Pjrt),
        other => Err(anyhow!(
            "unknown {BACKEND_ENV}={other:?} (expected \"native\" or \"pjrt\")"
        )),
    }
}

/// Resolve the KV pool budget in **bytes**: the explicit spec value when
/// given, else [`KV_BUDGET_ENV`], else the
/// [`DEFAULT_KV_BUDGET_MB`]-MiB default.
pub fn kv_budget_bytes(explicit: Option<usize>) -> Result<usize> {
    if let Some(bytes) = explicit {
        return Ok(bytes);
    }
    match std::env::var(KV_BUDGET_ENV) {
        Ok(v) => Ok(parse_kv_budget_mb(&v)? * 1024 * 1024),
        Err(_) => Ok(DEFAULT_KV_BUDGET_MB * 1024 * 1024),
    }
}

fn parse_kv_budget_mb(v: &str) -> Result<usize> {
    v.trim()
        .parse()
        .map_err(|_| anyhow!("{KV_BUDGET_ENV}={v:?} is not a whole MiB count (e.g. 64)"))
}

/// Resolve the prefill chunk size in tokens: the explicit spec value when
/// given, else [`PREFILL_CHUNK_ENV`], else `None` (whole-prompt
/// prefills). `Some(0)` from the spec is rejected like a malformed env
/// value — a zero-token chunk could never finish a prefill.
pub fn prefill_chunk(explicit: Option<usize>) -> Result<Option<usize>> {
    if let Some(chunk) = explicit {
        if chunk == 0 {
            return Err(anyhow!(
                "prefill_chunk=0 is not a positive token count (e.g. 32)"
            ));
        }
        return Ok(Some(chunk));
    }
    match std::env::var(PREFILL_CHUNK_ENV) {
        Ok(v) => Ok(Some(parse_prefill_chunk(&v)?)),
        Err(_) => Ok(None),
    }
}

fn parse_prefill_chunk(v: &str) -> Result<usize> {
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err(anyhow!(
            "{PREFILL_CHUNK_ENV}={v:?} is not a positive token count (e.g. 32)"
        )),
    }
}

/// Resolve the adaptive-recompression window in routed tokens: the
/// explicit spec value when given, else [`ADAPT_WINDOW_ENV`], else
/// [`DEFAULT_ADAPT_WINDOW`]. `Some(0)` from the spec is rejected like a
/// malformed env value — a zero-token window would recompress on every
/// executor iteration.
pub fn adapt_window(explicit: Option<u64>) -> Result<u64> {
    if let Some(w) = explicit {
        if w == 0 {
            return Err(anyhow!(
                "adapt window=0 is not a positive token count (e.g. 4096)"
            ));
        }
        return Ok(w);
    }
    match std::env::var(ADAPT_WINDOW_ENV) {
        Ok(v) => parse_adapt_window(&v),
        Err(_) => Ok(DEFAULT_ADAPT_WINDOW),
    }
}

fn parse_adapt_window(v: &str) -> Result<u64> {
    match v.trim().parse::<u64>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err(anyhow!(
            "{ADAPT_WINDOW_ENV}={v:?} is not a positive token count (e.g. 4096)"
        )),
    }
}

/// Resolve the total routed-token floor before the first adaptive
/// recompression: the explicit spec value when given, else
/// [`ADAPT_MIN_TOKENS_ENV`], else `0` (no warm-up floor beyond the
/// window itself). Zero is a legal value — unlike the window, a zero
/// floor is simply "no extra guard".
pub fn adapt_min_tokens(explicit: Option<u64>) -> Result<u64> {
    if let Some(n) = explicit {
        return Ok(n);
    }
    match std::env::var(ADAPT_MIN_TOKENS_ENV) {
        Ok(v) => parse_adapt_min_tokens(&v),
        Err(_) => Ok(0),
    }
}

fn parse_adapt_min_tokens(v: &str) -> Result<u64> {
    v.trim().parse::<u64>().map_err(|_| {
        anyhow!("{ADAPT_MIN_TOKENS_ENV}={v:?} is not a token count (e.g. 8192)")
    })
}

/// Resolve the serving replica count: the explicit spec value when
/// given, else [`REPLICAS_ENV`], else `1`. Zero replicas could never
/// serve a request, so `Some(0)` is rejected like a malformed env value.
pub fn replicas(explicit: Option<usize>) -> Result<usize> {
    if let Some(n) = explicit {
        if n == 0 {
            return Err(anyhow!("replicas=0 is not a positive replica count (e.g. 2)"));
        }
        return Ok(n);
    }
    match std::env::var(REPLICAS_ENV) {
        Ok(v) => parse_replicas(&v),
        Err(_) => Ok(1),
    }
}

fn parse_replicas(v: &str) -> Result<usize> {
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err(anyhow!(
            "{REPLICAS_ENV}={v:?} is not a positive replica count (e.g. 2)"
        )),
    }
}

/// Resolve the expert-parallel shard count: the explicit value when
/// given, else [`EXPERT_SHARDS_ENV`], else `1` (serial expert sweep).
/// `Some(0)` is rejected like a malformed env value — zero shards would
/// execute no experts at all.
pub fn expert_shards(explicit: Option<usize>) -> Result<usize> {
    if let Some(n) = explicit {
        if n == 0 {
            return Err(anyhow!(
                "expert_shards=0 is not a positive shard count (e.g. 4)"
            ));
        }
        return Ok(n);
    }
    match std::env::var(EXPERT_SHARDS_ENV) {
        Ok(v) => parse_expert_shards(&v),
        Err(_) => Ok(1),
    }
}

fn parse_expert_shards(v: &str) -> Result<usize> {
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err(anyhow!(
            "{EXPERT_SHARDS_ENV}={v:?} is not a positive shard count (e.g. 4)"
        )),
    }
}

/// Resolve the HTTP front-end listen address: the explicit value when
/// given, else [`HTTP_ADDR_ENV`], else `None` (no HTTP front end). An
/// empty address — explicit or from the environment — is rejected; the
/// operator should unset the knob instead.
pub fn http_addr(explicit: Option<String>) -> Result<Option<String>> {
    if let Some(addr) = explicit {
        return Ok(Some(parse_http_addr(&addr)?));
    }
    match std::env::var(HTTP_ADDR_ENV) {
        Ok(v) => Ok(Some(parse_http_addr(&v)?)),
        Err(_) => Ok(None),
    }
}

fn parse_http_addr(v: &str) -> Result<String> {
    let addr = v.trim();
    if addr.is_empty() || !addr.contains(':') {
        return Err(anyhow!(
            "{HTTP_ADDR_ENV}={v:?} is not a host:port listen address (e.g. 127.0.0.1:8089)"
        ));
    }
    Ok(addr.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parses_known_names_and_rejects_others() {
        assert_eq!(parse_backend("native").unwrap(), BackendKind::Native);
        assert_eq!(parse_backend("").unwrap(), BackendKind::Native);
        assert_eq!(parse_backend("pjrt").unwrap(), BackendKind::Pjrt);
        let err = parse_backend("cuda").unwrap_err().to_string();
        assert!(err.contains("HCSMOE_BACKEND"), "{err}");
        assert!(err.contains("expected"), "{err}");
    }

    #[test]
    fn kv_budget_parses_mib_counts() {
        assert_eq!(parse_kv_budget_mb("64").unwrap(), 64);
        assert_eq!(parse_kv_budget_mb(" 8 ").unwrap(), 8);
        let err = parse_kv_budget_mb("lots").unwrap_err().to_string();
        assert!(err.contains("HCSMOE_KV_BUDGET_MB"), "{err}");
        // explicit spec bytes win without consulting the environment
        assert_eq!(kv_budget_bytes(Some(12345)).unwrap(), 12345);
    }

    #[test]
    fn prefill_chunk_requires_a_positive_count() {
        assert_eq!(parse_prefill_chunk("32").unwrap(), 32);
        assert_eq!(parse_prefill_chunk("1").unwrap(), 1);
        for bad in ["0", "-4", "many", ""] {
            let err = parse_prefill_chunk(bad).unwrap_err().to_string();
            assert!(err.contains("HCSMOE_PREFILL_CHUNK"), "{err}");
        }
        // explicit spec values win, and zero is rejected at startup
        assert_eq!(prefill_chunk(Some(16)).unwrap(), Some(16));
        assert!(prefill_chunk(Some(0)).is_err());
    }

    #[test]
    fn adapt_window_requires_a_positive_count() {
        assert_eq!(parse_adapt_window("4096").unwrap(), 4096);
        assert_eq!(parse_adapt_window(" 1 ").unwrap(), 1);
        for bad in ["0", "-4", "soon", ""] {
            let err = parse_adapt_window(bad).unwrap_err().to_string();
            assert!(err.contains("HCSMOE_ADAPT_WINDOW"), "{err}");
        }
        // explicit spec values win, and zero is rejected at startup
        assert_eq!(adapt_window(Some(64)).unwrap(), 64);
        assert!(adapt_window(Some(0)).is_err());
    }

    #[test]
    fn adapt_min_tokens_parses_counts_and_allows_zero() {
        assert_eq!(parse_adapt_min_tokens("8192").unwrap(), 8192);
        assert_eq!(parse_adapt_min_tokens("0").unwrap(), 0);
        for bad in ["-1", "never", ""] {
            let err = parse_adapt_min_tokens(bad).unwrap_err().to_string();
            assert!(err.contains("HCSMOE_ADAPT_MIN_TOKENS"), "{err}");
        }
        // explicit spec values win without consulting the environment
        assert_eq!(adapt_min_tokens(Some(7)).unwrap(), 7);
        assert_eq!(adapt_min_tokens(Some(0)).unwrap(), 0);
    }

    #[test]
    fn replicas_require_a_positive_count() {
        assert_eq!(parse_replicas("2").unwrap(), 2);
        assert_eq!(parse_replicas(" 1 ").unwrap(), 1);
        for bad in ["0", "-1", "all", ""] {
            let err = parse_replicas(bad).unwrap_err().to_string();
            assert!(err.contains("HCSMOE_REPLICAS"), "{err}");
        }
        // explicit spec values win, and zero is rejected at startup
        assert_eq!(replicas(Some(4)).unwrap(), 4);
        assert!(replicas(Some(0)).is_err());
    }

    #[test]
    fn expert_shards_require_a_positive_count() {
        assert_eq!(parse_expert_shards("4").unwrap(), 4);
        assert_eq!(parse_expert_shards(" 1 ").unwrap(), 1);
        for bad in ["0", "-2", "auto", ""] {
            let err = parse_expert_shards(bad).unwrap_err().to_string();
            assert!(err.contains("HCSMOE_EXPERT_SHARDS"), "{err}");
        }
        // explicit values win, and zero is rejected at startup
        assert_eq!(expert_shards(Some(3)).unwrap(), 3);
        assert!(expert_shards(Some(0)).is_err());
    }

    #[test]
    fn http_addr_requires_host_and_port() {
        assert_eq!(parse_http_addr("127.0.0.1:8089").unwrap(), "127.0.0.1:8089");
        assert_eq!(parse_http_addr(" 0.0.0.0:80 ").unwrap(), "0.0.0.0:80");
        for bad in ["", "   ", "localhost"] {
            let err = parse_http_addr(bad).unwrap_err().to_string();
            assert!(err.contains("HCSMOE_HTTP_ADDR"), "{err}");
        }
        // explicit values win without consulting the environment
        assert_eq!(
            http_addr(Some("[::1]:9000".into())).unwrap().as_deref(),
            Some("[::1]:9000")
        );
        assert!(http_addr(Some(String::new())).is_err());
    }
}
