//! Zero-shot evaluation harness — the LM-Harness analog (Section 4.1).
//!
//! Multiple-choice scoring exactly as the paper's `acc` metric: each choice
//! is appended to the prompt, scored by length-normalised sequence
//! log-likelihood under the model, and the argmax choice is compared to the
//! gold answer.  Also: macro precision/recall/F1 (Table 15) and perplexity
//! over token streams.

use std::collections::HashMap;

use anyhow::{ensure, Result};

use crate::data::{Benchmark, TokenStream};
use crate::model::{LoadedModel, ModelContext};

/// Scores of one task.
#[derive(Debug, Clone)]
pub struct TaskScore {
    /// Task name.
    pub task: String,
    /// Fraction of items answered correctly.
    pub accuracy: f64,
    /// Item count.
    pub n_items: usize,
    /// per-item predicted choice (for P/R/F1 and error analysis)
    pub predictions: Vec<usize>,
    /// per-item gold choice
    pub golds: Vec<usize>,
}

/// Macro-averaged precision/recall/F1 plus plain accuracy (Table 15).
#[derive(Debug, Clone, Copy)]
pub struct Prf {
    /// Macro-averaged precision.
    pub precision: f64,
    /// Macro-averaged recall.
    pub recall: f64,
    /// Macro-averaged F1.
    pub f1: f64,
    /// Plain accuracy.
    pub accuracy: f64,
}

/// Zero-shot evaluation harness bound to one [`ModelContext`] (caches
/// loaded benchmarks per task).
pub struct Evaluator<'a> {
    ctx: &'a ModelContext,
    cache: std::cell::RefCell<HashMap<String, Benchmark>>,
}

impl<'a> Evaluator<'a> {
    /// Build an evaluator over `ctx`'s artifact set.
    pub fn new(ctx: &'a ModelContext) -> Result<Self> {
        Ok(Self { ctx, cache: Default::default() })
    }

    fn benchmark(&self, task: &str) -> Result<Benchmark> {
        if let Some(b) = self.cache.borrow().get(task) {
            return Ok(b.clone());
        }
        let b = Benchmark::load(self.ctx.arts.benchmark(task))?;
        self.cache.borrow_mut().insert(task.to_string(), b.clone());
        Ok(b)
    }

    /// Score one benchmark with batched PJRT executions.
    pub fn score_benchmark(&self, model: &LoadedModel, bench: &Benchmark) -> Result<TaskScore> {
        let (bsz, t) = (self.ctx.manifest.eval_b, self.ctx.manifest.eval_t);
        // build rows: one per (item, choice)
        struct RowMeta {
            item: usize,
            choice: usize,
            start: usize, // first predicted position (prompt_len)
            end: usize,   // seq len
        }
        let mut rows: Vec<Vec<i32>> = Vec::new();
        let mut metas: Vec<RowMeta> = Vec::new();
        for (ii, item) in bench.items.iter().enumerate() {
            for (ci, ch) in item.choices.iter().enumerate() {
                let mut seq: Vec<i32> = item.prompt.clone();
                seq.extend_from_slice(ch);
                ensure!(seq.len() <= t, "item longer than eval_t={t}");
                let end = seq.len();
                seq.resize(t, crate::data::vocab::PAD);
                rows.push(seq);
                metas.push(RowMeta { item: ii, choice: ci, start: item.prompt.len(), end });
            }
        }
        // batched scoring
        let mut scores: Vec<Vec<f64>> =
            vec![vec![f64::NEG_INFINITY; bench.n_choices]; bench.items.len()];
        for (chunk_rows, chunk_metas) in rows.chunks(bsz).zip(metas.chunks(bsz)) {
            let mut ids = Vec::with_capacity(bsz * t);
            for r in chunk_rows {
                ids.extend_from_slice(r);
            }
            ids.resize(bsz * t, crate::data::vocab::PAD);
            let logits = self.ctx.run_logits(model, &ids)?;
            let v = logits.shape()[2];
            let ld = logits.data();
            for (bi, meta) in chunk_metas.iter().enumerate() {
                let mut lp = 0f64;
                // position 0 has no conditioning context: an empty prompt
                // starts scoring at position 1 (same guard as the serving
                // batcher's flush path)
                for pos in meta.start.max(1)..meta.end {
                    // predict token at `pos` from logits at `pos - 1`
                    let row = &ld[(bi * t + pos - 1) * v..(bi * t + pos) * v];
                    let tok = chunk_rows[bi][pos] as usize;
                    lp += log_softmax_at(row, tok);
                }
                // normalise by the number of positions actually scored
                // (start==0 skips position 0, so the divisor must too)
                let scored = (meta.end.saturating_sub(meta.start.max(1))).max(1);
                scores[meta.item][meta.choice] = lp / scored as f64;
            }
        }
        // argmax per item
        let mut correct = 0usize;
        let mut predictions = Vec::with_capacity(bench.items.len());
        let mut golds = Vec::with_capacity(bench.items.len());
        for (ii, s) in scores.iter().enumerate() {
            let pred = s
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            predictions.push(pred);
            golds.push(bench.items[ii].answer);
            if pred == bench.items[ii].answer {
                correct += 1;
            }
        }
        Ok(TaskScore {
            task: bench.name.clone(),
            accuracy: correct as f64 / bench.items.len() as f64,
            n_items: bench.items.len(),
            predictions,
            golds,
        })
    }

    /// Accuracy of `model` on one named task.
    pub fn accuracy(&self, model: &LoadedModel, task: &str) -> Result<f64> {
        Ok(self.score_benchmark(model, &self.benchmark(task)?)?.accuracy)
    }

    /// Evaluate a suite of tasks; returns (task, accuracy) plus the average.
    pub fn eval_suite(
        &self,
        model: &LoadedModel,
        tasks: &[String],
    ) -> Result<(Vec<(String, f64)>, f64)> {
        let mut out = Vec::with_capacity(tasks.len());
        for task in tasks {
            out.push((task.clone(), self.accuracy(model, task)?));
        }
        let avg = out.iter().map(|(_, a)| a).sum::<f64>() / out.len().max(1) as f64;
        Ok((out, avg))
    }

    /// Macro precision/recall/F1 over predicted classes (Table 15 protocol).
    pub fn prf(&self, model: &LoadedModel, task: &str) -> Result<Prf> {
        let bench = self.benchmark(task)?;
        let ts = self.score_benchmark(model, &bench)?;
        Ok(macro_prf(&ts.predictions, &ts.golds, bench.n_choices))
    }

    /// Perplexity over a token stream (windows of eval_t).
    pub fn perplexity(&self, model: &LoadedModel, stream: &TokenStream) -> Result<f64> {
        let (bsz, t) = (self.ctx.manifest.eval_b, self.ctx.manifest.eval_t);
        let mut nll = 0f64;
        let mut count = 0usize;
        for batch in stream.tokens.chunks_exact(bsz * t).take(4) {
            let logits = self.ctx.run_logits(model, batch)?;
            let v = logits.shape()[2];
            let ld = logits.data();
            for bi in 0..bsz {
                for pos in 1..t {
                    let row = &ld[(bi * t + pos - 1) * v..(bi * t + pos) * v];
                    let tok = batch[bi * t + pos] as usize;
                    nll -= log_softmax_at(row, tok);
                    count += 1;
                }
            }
        }
        ensure!(count > 0, "stream too short for one ppl batch");
        Ok((nll / count as f64).exp())
    }
}

/// log softmax(row)[tok] without materialising the full distribution.
pub fn log_softmax_at(row: &[f32], tok: usize) -> f64 {
    let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut z = 0f64;
    for &x in row {
        z += ((x as f64) - mx).exp();
    }
    (row[tok] as f64) - mx - z.ln()
}

/// Macro-averaged precision/recall/F1.
pub fn macro_prf(pred: &[usize], gold: &[usize], n_classes: usize) -> Prf {
    let mut tp = vec![0f64; n_classes];
    let mut fp = vec![0f64; n_classes];
    let mut fne = vec![0f64; n_classes];
    let mut correct = 0usize;
    for (&p, &g) in pred.iter().zip(gold) {
        if p == g {
            tp[p] += 1.0;
            correct += 1;
        } else {
            fp[p] += 1.0;
            fne[g] += 1.0;
        }
    }
    let mut prec = 0f64;
    let mut rec = 0f64;
    let mut f1 = 0f64;
    for c in 0..n_classes {
        let p = if tp[c] + fp[c] > 0.0 { tp[c] / (tp[c] + fp[c]) } else { 0.0 };
        let r = if tp[c] + fne[c] > 0.0 { tp[c] / (tp[c] + fne[c]) } else { 0.0 };
        prec += p;
        rec += r;
        f1 += if p + r > 0.0 { 2.0 * p * r / (p + r) } else { 0.0 };
    }
    let n = n_classes as f64;
    Prf {
        precision: prec / n,
        recall: rec / n,
        f1: f1 / n,
        accuracy: correct as f64 / pred.len().max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_matches_manual() {
        let row = [1.0f32, 2.0, 3.0];
        let z: f64 = row.iter().map(|&x| (x as f64).exp()).sum();
        for (i, &x) in row.iter().enumerate() {
            let expect = (x as f64) - z.ln();
            assert!((log_softmax_at(&row, i) - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn log_softmax_is_stable_for_large_logits() {
        let row = [1000.0f32, 999.0, 998.0];
        let lp = log_softmax_at(&row, 0);
        assert!(lp.is_finite() && lp < 0.0);
    }

    #[test]
    fn prf_perfect_and_worst() {
        let p = macro_prf(&[0, 1, 2, 3], &[0, 1, 2, 3], 4);
        assert_eq!(p.accuracy, 1.0);
        assert!((p.f1 - 1.0).abs() < 1e-9);
        let w = macro_prf(&[1, 2, 3, 0], &[0, 1, 2, 3], 4);
        assert_eq!(w.accuracy, 0.0);
        assert_eq!(w.f1, 0.0);
    }

    #[test]
    fn prf_partial() {
        // classes: two items of class 0, predicted [0, 1]
        let p = macro_prf(&[0, 1], &[0, 0], 2);
        assert!((p.accuracy - 0.5).abs() < 1e-9);
        // class 0: tp=1 fp=0 fn=1 -> p=1, r=0.5, f1=2/3; class 1: tp=0 fp=1 -> 0
        assert!((p.precision - 0.5).abs() < 1e-9);
        assert!((p.recall - 0.25).abs() < 1e-9);
        assert!((p.f1 - (2.0 / 3.0) / 2.0).abs() < 1e-9);
    }
}
