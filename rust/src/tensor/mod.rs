//! Dense f32 tensor with the small linear-algebra surface the compression
//! algorithms need (no BLAS offline; sizes here are tiny — n_experts ≤ 64,
//! d/m ≤ a few hundred — so simple loops suffice, with a blocked matmul for
//! the ZipIt/Fix-Dom correlation path).

use std::fmt;

use anyhow::{anyhow, Result};

use crate::parallel;

/// Dense row-major f32 tensor (shape + flat data).
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)
    }
}

impl Tensor {
    /// Build a tensor, checking that `data` fills `shape` exactly.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {shape:?} wants {n} elems, got {}", data.len()));
        }
        Ok(Self { shape, data })
    }

    /// All-zero tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    /// Constant-filled tensor of the given shape.
    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![v; n] }
    }

    /// 1-D tensor wrapping `data`.
    pub fn from_vec(data: Vec<f32>) -> Self {
        Self { shape: vec![data.len()], data }
    }

    /// Dimensions, outermost first.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Flat row-major element view.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat element view.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume the tensor, returning its flat data.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret under a new shape with the same element count.
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(anyhow!("cannot reshape {:?} to {shape:?}", self.shape));
        }
        self.shape = shape;
        Ok(self)
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.shape.len(), 2, "row() needs 2-D");
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// Sub-tensor at leading index `i` (e.g. expert slice of [n, d, m]).
    pub fn index(&self, i: usize) -> Tensor {
        assert!(!self.shape.is_empty() && i < self.shape[0]);
        let inner: usize = self.shape[1..].iter().product();
        Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * inner..(i + 1) * inner].to_vec(),
        }
    }

    /// Write `src` into leading index `i`.
    pub fn set_index(&mut self, i: usize, src: &Tensor) {
        let inner: usize = self.shape[1..].iter().product();
        assert_eq!(src.len(), inner, "set_index size mismatch");
        self.data[i * inner..(i + 1) * inner].copy_from_slice(&src.data);
    }

    /// Multiply every element by `s` in place.
    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// `self += s * other`, element-wise (shapes must match).
    pub fn add_scaled(&mut self, other: &Tensor, s: f32) {
        assert_eq!(self.shape, other.shape, "add_scaled shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Euclidean norm over all elements.
    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

/// Weighted sum of tensors (the merging primitive: Ê = Σ α_j E_j).
pub fn weighted_sum(tensors: &[&Tensor], weights: &[f32]) -> Result<Tensor> {
    if tensors.is_empty() || tensors.len() != weights.len() {
        return Err(anyhow!("weighted_sum needs matching non-empty inputs"));
    }
    let mut out = Tensor::zeros(tensors[0].shape().to_vec());
    for (t, &w) in tensors.iter().zip(weights) {
        out.add_scaled(t, w);
    }
    Ok(out)
}

// --------------------------------------------------------------------------
// Vector helpers over &[f32] (similarity metrics, clustering)
// --------------------------------------------------------------------------

/// Dot product of two equal-length vectors.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean distance between two equal-length vectors.
pub fn l2_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt()
}

/// Cosine similarity (0 when either vector is all-zero).
pub fn cosine_sim(a: &[f32], b: &[f32]) -> f32 {
    let na = dot(a, a).sqrt();
    let nb = dot(b, b).sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot(a, b) / (na * nb)
}

/// Cosine distance: `1 - cosine_sim`.
pub fn cosine_dist(a: &[f32], b: &[f32]) -> f32 {
    1.0 - cosine_sim(a, b)
}

/// Gather rows of a row-major `[*, row_len]` buffer into a dense block —
/// the MoE dispatch primitive: routed token rows are packed contiguously
/// so each expert runs one grouped GEMM instead of per-token products.
/// Row indices may repeat (a token routed to the same physical slot by
/// two top-k selections appears twice).
pub fn gather_rows(src: &[f32], row_len: usize, rows: &[usize]) -> Vec<f32> {
    let mut out = vec![0f32; rows.len() * row_len];
    for (dst, &r) in out.chunks_mut(row_len).zip(rows) {
        dst.copy_from_slice(&src[r * row_len..(r + 1) * row_len]);
    }
    out
}

/// C[M,N] = A[M,K] @ B[K,N], simple ikj loop (cache-friendly) — the serial
/// reference for [`matmul_blocked_with`].
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        matmul_row(&a[i * k..(i + 1) * k], b, k, n, 0..n, &mut c[i * n..(i + 1) * n]);
    }
    c
}

/// One output row over a column block: per element, contributions accumulate
/// in ascending kk — the single reduction order every matmul variant here
/// uses, which is what makes blocked/parallel results bit-identical.
#[inline]
fn matmul_row(
    arow: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    jrange: std::ops::Range<usize>,
    crow: &mut [f32],
) {
    for kk in 0..k {
        let av = arow[kk];
        if av == 0.0 {
            continue;
        }
        let brow = &b[kk * n..(kk + 1) * n];
        for j in jrange.clone() {
            crow[j] += av * brow[j];
        }
    }
}

/// Column-block width for the blocked matmul: 128 f32 = two 256-byte rows,
/// small enough that a B-panel stays cache-resident across the kk sweep.
const MATMUL_J_BLOCK: usize = 128;

/// Blocked + row-parallel matmul: output rows are partitioned across scoped
/// threads (disjoint `&mut` row chunks), and each row sweeps B in
/// [`MATMUL_J_BLOCK`]-wide column panels. Per output element the
/// accumulation order is the serial kernel's ascending-kk order, so the
/// result is bit-identical to [`matmul`] at any thread count.
pub fn matmul_blocked_with(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    if n == 0 || m == 0 {
        return c;
    }
    let row_block = |i0: usize, crows: &mut [f32]| {
        for (off, crow) in crows.chunks_mut(n).enumerate() {
            let i = i0 + off;
            let arow = &a[i * k..(i + 1) * k];
            let mut j0 = 0;
            while j0 < n {
                let j1 = (j0 + MATMUL_J_BLOCK).min(n);
                matmul_row(arow, b, k, n, j0..j1, crow);
                j0 = j1;
            }
        }
    };
    parallel::par_row_chunks_mut(threads, &mut c, n, row_block);
    c
}

/// Pearson correlation matrix between rows of X [p, t] and rows of Y [q, t]
/// (auto-parallel over output rows; the ZipIt path builds O((|C|·m)²)
/// correlations through this, the dominant merge-time cost).
pub fn corr_matrix(x: &[f32], y: &[f32], p: usize, q: usize, t: usize) -> Vec<f32> {
    let threads = parallel::default_threads();
    let auto = if p * q * t >= parallel::PAR_AUTO_WORK { threads } else { 1 };
    corr_matrix_with(x, y, p, q, t, auto)
}

/// [`corr_matrix`] with an explicit worker count. Output rows are disjoint
/// and each entry is one `dot(xi, yj) * xn[i] * yn[j]` — identical operand
/// order at any thread count, so results are bit-identical to serial.
pub fn corr_matrix_with(
    x: &[f32],
    y: &[f32],
    p: usize,
    q: usize,
    t: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), p * t);
    assert_eq!(y.len(), q * t);
    let norm = |v: &[f32]| -> (Vec<f32>, Vec<f32>) {
        let rows = v.len() / t;
        let mut centered = vec![0.0f32; v.len()];
        let mut inv_norm = vec![0.0f32; rows];
        for r in 0..rows {
            let row = &v[r * t..(r + 1) * t];
            let mean = row.iter().sum::<f32>() / t as f32;
            let dst = &mut centered[r * t..(r + 1) * t];
            for (d, s) in dst.iter_mut().zip(row) {
                *d = s - mean;
            }
            let nrm = dot(dst, dst).sqrt();
            inv_norm[r] = if nrm > 1e-12 { 1.0 / nrm } else { 0.0 };
        }
        (centered, inv_norm)
    };
    let (xc, xn) = norm(x);
    let (yc, yn) = norm(y);
    let mut c = vec![0.0f32; p * q];
    if q == 0 || p == 0 {
        return c;
    }
    let fill = |i0: usize, crows: &mut [f32]| {
        for (off, crow) in crows.chunks_mut(q).enumerate() {
            let i = i0 + off;
            let xi = &xc[i * t..(i + 1) * t];
            for (j, slot) in crow.iter_mut().enumerate() {
                let yj = &yc[j * t..(j + 1) * t];
                *slot = dot(xi, yj) * xn[i] * yn[j];
            }
        }
    };
    parallel::par_row_chunks_mut(threads, &mut c, q, fill);
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn index_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.index(1).data(), &[4., 5., 6.]);
        let mut t2 = t.clone();
        t2.set_index(0, &Tensor::from_vec(vec![7., 8., 9.]));
        assert_eq!(t2.row(0), &[7., 8., 9.]);
    }

    #[test]
    fn weighted_sum_merging() {
        let a = Tensor::from_vec(vec![1.0, 0.0]);
        let b = Tensor::from_vec(vec![0.0, 1.0]);
        let m = weighted_sum(&[&a, &b], &[0.25, 0.75]).unwrap();
        assert_eq!(m.data(), &[0.25, 0.75]);
    }

    #[test]
    fn distances() {
        let a = [1.0, 0.0];
        let b = [0.0, 1.0];
        assert!((l2_dist(&a, &b) - 2f32.sqrt()).abs() < 1e-6);
        assert!(cosine_sim(&a, &b).abs() < 1e-6);
        assert!((cosine_dist(&a, &a)).abs() < 1e-6);
    }

    #[test]
    fn gather_rows_packs_and_repeats() {
        let src = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3 rows of len 2
        assert_eq!(gather_rows(&src, 2, &[2, 0, 2]), vec![5.0, 6.0, 1.0, 2.0, 5.0, 6.0]);
        assert!(gather_rows(&src, 2, &[]).is_empty());
    }

    #[test]
    fn matmul_small() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let c = matmul(&[1., 2., 3., 4.], &[5., 6., 7., 8.], 2, 2, 2);
        assert_eq!(c, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn blocked_parallel_matmul_is_bit_identical() {
        let mut rng = crate::util::Rng::new(77);
        let (m, k, n) = (13, 31, 157); // odd sizes cross the j-block boundary
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let serial = matmul(&a, &b, m, k, n);
        for threads in [1usize, 2, 3, 8] {
            let par = matmul_blocked_with(&a, &b, m, k, n, threads);
            let same = serial
                .iter()
                .zip(&par)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "threads={threads}");
        }
    }

    #[test]
    fn parallel_corr_matrix_is_bit_identical() {
        let mut rng = crate::util::Rng::new(78);
        let (p, q, t) = (9, 7, 33);
        let x: Vec<f32> = (0..p * t).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..q * t).map(|_| rng.normal() as f32).collect();
        let serial = corr_matrix_with(&x, &y, p, q, t, 1);
        for threads in [2usize, 3, 5] {
            let par = corr_matrix_with(&x, &y, p, q, t, threads);
            let same = serial
                .iter()
                .zip(&par)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "threads={threads}");
        }
    }

    #[test]
    fn corr_perfect() {
        // row correlated with itself = 1, with its negation = -1
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let y = [-1.0f32, -2.0, -3.0, -4.0];
        let c = corr_matrix(&x, &y, 1, 1, 4);
        assert!((c[0] + 1.0).abs() < 1e-5);
        let c2 = corr_matrix(&x, &x, 1, 1, 4);
        assert!((c2[0] - 1.0).abs() < 1e-5);
    }
}
