//! Dense f32 tensor with the small linear-algebra surface the compression
//! algorithms need, plus the GEMM core every backend hot path bottoms out
//! in. The GEMM is a cache-blocked, autovectorization-friendly microkernel
//! ([`GEMM_MR`]×[`GEMM_NR`] register tiles) pinned bit-identical to the
//! scalar [`matmul_reference`] expression; an int8 per-row-quantized
//! variant ([`matmul_q8_with`]) serves post-merge compressed experts.

use std::fmt;

use anyhow::{anyhow, Result};

use crate::parallel;

/// Dense row-major f32 tensor (shape + flat data).
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)
    }
}

impl Tensor {
    /// Build a tensor, checking that `data` fills `shape` exactly.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {shape:?} wants {n} elems, got {}", data.len()));
        }
        Ok(Self { shape, data })
    }

    /// All-zero tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    /// Constant-filled tensor of the given shape.
    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![v; n] }
    }

    /// 1-D tensor wrapping `data`.
    pub fn from_vec(data: Vec<f32>) -> Self {
        Self { shape: vec![data.len()], data }
    }

    /// Dimensions, outermost first.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Flat row-major element view.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat element view.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume the tensor, returning its flat data.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret under a new shape with the same element count.
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(anyhow!("cannot reshape {:?} to {shape:?}", self.shape));
        }
        self.shape = shape;
        Ok(self)
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.shape.len(), 2, "row() needs 2-D");
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// Sub-tensor at leading index `i` (e.g. expert slice of [n, d, m]).
    pub fn index(&self, i: usize) -> Tensor {
        assert!(!self.shape.is_empty() && i < self.shape[0]);
        let inner: usize = self.shape[1..].iter().product();
        Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * inner..(i + 1) * inner].to_vec(),
        }
    }

    /// Write `src` into leading index `i`.
    pub fn set_index(&mut self, i: usize, src: &Tensor) {
        let inner: usize = self.shape[1..].iter().product();
        assert_eq!(src.len(), inner, "set_index size mismatch");
        self.data[i * inner..(i + 1) * inner].copy_from_slice(&src.data);
    }

    /// Multiply every element by `s` in place.
    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// `self += s * other`, element-wise (shapes must match).
    pub fn add_scaled(&mut self, other: &Tensor, s: f32) {
        assert_eq!(self.shape, other.shape, "add_scaled shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Euclidean norm over all elements.
    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

/// Weighted sum of tensors (the merging primitive: Ê = Σ α_j E_j).
pub fn weighted_sum(tensors: &[&Tensor], weights: &[f32]) -> Result<Tensor> {
    if tensors.is_empty() || tensors.len() != weights.len() {
        return Err(anyhow!("weighted_sum needs matching non-empty inputs"));
    }
    let mut out = Tensor::zeros(tensors[0].shape().to_vec());
    for (t, &w) in tensors.iter().zip(weights) {
        out.add_scaled(t, w);
    }
    Ok(out)
}

// --------------------------------------------------------------------------
// Vector helpers over &[f32] (similarity metrics, clustering)
// --------------------------------------------------------------------------

/// Dot product of two equal-length vectors.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean distance between two equal-length vectors.
pub fn l2_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt()
}

/// Cosine similarity (0 when either vector is all-zero).
pub fn cosine_sim(a: &[f32], b: &[f32]) -> f32 {
    let na = dot(a, a).sqrt();
    let nb = dot(b, b).sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot(a, b) / (na * nb)
}

/// Cosine distance: `1 - cosine_sim`.
pub fn cosine_dist(a: &[f32], b: &[f32]) -> f32 {
    1.0 - cosine_sim(a, b)
}

/// Gather rows of a row-major `[*, row_len]` buffer into a dense block —
/// the MoE dispatch primitive: routed token rows are packed contiguously
/// so each expert runs one grouped GEMM instead of per-token products.
/// Row indices may repeat (a token routed to the same physical slot by
/// two top-k selections appears twice).
pub fn gather_rows(src: &[f32], row_len: usize, rows: &[usize]) -> Vec<f32> {
    let mut out = vec![0f32; rows.len() * row_len];
    for (dst, &r) in out.chunks_mut(row_len).zip(rows) {
        dst.copy_from_slice(&src[r * row_len..(r + 1) * row_len]);
    }
    out
}

/// C[M,N] = A[M,K] @ B[K,N] — the canonical scalar expression. Per output
/// element, contributions accumulate in ascending kk; this single reduction
/// order is the contract every fast path here reproduces bit-for-bit, which
/// is why the tiled/parallel kernels can be pinned against this function.
/// The `av == 0.0` skip adds only `±0.0` terms when taken, and (for finite
/// inputs, accumulating from `+0.0`) such terms never change the
/// accumulator's bits — so skipping is bit-equivalent to not skipping.
pub fn matmul_reference(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for kk in 0..k {
            let av = arow[kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    c
}

/// C[M,N] = A[M,K] @ B[K,N], serial tiled kernel — bit-identical to
/// [`matmul_reference`] for finite inputs (same ascending-kk reduction
/// order per element; see the microkernel notes on [`GEMM_MR`]).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_blocked_with(a, b, m, k, n, 1)
}

/// Register-tile height: each microkernel invocation produces `GEMM_MR`
/// rows × [`GEMM_NR`] columns of C in local accumulators. The kk loop is
/// the outer loop of the kernel and runs ascending over the full reduction
/// (no k-blocking), so every C element is the reference's
/// `((0 + t0) + t1) + …` left fold exactly — tiles only reorder *which*
/// elements are computed when, never the reduction within one element,
/// keeping the tiled result bit-identical to [`matmul_reference`].
const GEMM_MR: usize = 4;

/// Register-tile width in f32 lanes: 16 = one 64-byte cache line, two AVX2
/// vectors or one AVX-512 vector. The inner `cc` loop over a contiguous
/// B panel is a fixed-trip-count loop LLVM unrolls and autovectorizes.
const GEMM_NR: usize = 16;

/// Full [`GEMM_MR`]×[`GEMM_NR`] microkernel: C tile rows start at local
/// row `r0` of `crows` (a chunk whose first row is global row `i`), column
/// `j`. Accumulators live in registers; B is read in contiguous
/// [`GEMM_NR`]-lane panels.
#[inline]
#[allow(clippy::too_many_arguments)]
fn gemm_kernel_full(
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    i: usize,
    j: usize,
    r0: usize,
    crows: &mut [f32],
) {
    let mut acc = [[0.0f32; GEMM_NR]; GEMM_MR];
    let arows: [&[f32]; GEMM_MR] = [
        &a[i * k..(i + 1) * k],
        &a[(i + 1) * k..(i + 2) * k],
        &a[(i + 2) * k..(i + 3) * k],
        &a[(i + 3) * k..(i + 4) * k],
    ];
    for kk in 0..k {
        let bp = &b[kk * n + j..kk * n + j + GEMM_NR];
        for (accr, arow) in acc.iter_mut().zip(arows) {
            let av = arow[kk];
            for (slot, bv) in accr.iter_mut().zip(bp) {
                *slot += av * bv;
            }
        }
    }
    for (rr, accr) in acc.iter().enumerate() {
        crows[(r0 + rr) * n + j..(r0 + rr) * n + j + GEMM_NR].copy_from_slice(accr);
    }
}

/// Edge microkernel for partial tiles (`mr < GEMM_MR` and/or
/// `nr < GEMM_NR`): same loop structure and the same ascending-kk
/// accumulation, just with runtime trip counts.
#[inline]
#[allow(clippy::too_many_arguments)]
fn gemm_kernel_edge(
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    i: usize,
    j: usize,
    mr: usize,
    nr: usize,
    r0: usize,
    crows: &mut [f32],
) {
    let mut acc = [[0.0f32; GEMM_NR]; GEMM_MR];
    for kk in 0..k {
        let bp = &b[kk * n + j..kk * n + j + nr];
        for (rr, accr) in acc.iter_mut().enumerate().take(mr) {
            let av = a[(i + rr) * k + kk];
            for (slot, bv) in accr.iter_mut().zip(bp) {
                *slot += av * bv;
            }
        }
    }
    for (rr, accr) in acc.iter().enumerate().take(mr) {
        crows[(r0 + rr) * n + j..(r0 + rr) * n + j + nr].copy_from_slice(&accr[..nr]);
    }
}

/// Tile a chunk of output rows (`crows`, starting at global row `i0`)
/// through the register microkernels. The tile schedule is deterministic
/// and pinned: row tiles ascending by [`GEMM_MR`], column tiles ascending
/// by [`GEMM_NR`], edges last in each dimension.
fn gemm_rows(a: &[f32], b: &[f32], k: usize, n: usize, i0: usize, crows: &mut [f32]) {
    let rows = crows.len() / n;
    let mut r = 0;
    while r < rows {
        let mr = GEMM_MR.min(rows - r);
        let mut j = 0;
        while j < n {
            let nr = GEMM_NR.min(n - j);
            if mr == GEMM_MR && nr == GEMM_NR {
                gemm_kernel_full(a, b, k, n, i0 + r, j, r, crows);
            } else {
                gemm_kernel_edge(a, b, k, n, i0 + r, j, mr, nr, r, crows);
            }
            j += nr;
        }
        r += mr;
    }
}

/// Tiled + row-parallel matmul: output rows are partitioned across scoped
/// threads (disjoint `&mut` row chunks), and each chunk runs the
/// [`GEMM_MR`]×[`GEMM_NR`] register-tiled microkernel sweep. Chunk
/// boundaries and tile order never change any element's ascending-kk
/// reduction, so the result is bit-identical to [`matmul_reference`] (and
/// to itself) at any thread count for finite inputs.
pub fn matmul_blocked_with(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    if n == 0 || m == 0 {
        return c;
    }
    let row_block = |i0: usize, crows: &mut [f32]| gemm_rows(a, b, k, n, i0, crows);
    parallel::par_row_chunks_mut(threads, &mut c, n, row_block);
    c
}

// --------------------------------------------------------------------------
// Int8 per-row quantization + quantized GEMM (post-merge expert weights)
// --------------------------------------------------------------------------

/// Symmetric int8 quantization range: `q ∈ [-127, 127]` (−128 unused so
/// the scale maps `±maxabs` exactly onto `±QUANT_I8_MAX`).
pub const QUANT_I8_MAX: f32 = 127.0;

/// Per-row symmetric int8 quantization of a row-major `[rows, cols]`
/// matrix: `scale[r] = maxabs(row r) / 127`, `q = round(w / scale)`
/// clamped to `±127`. All-zero rows get scale 1.0 (dequantizes to exact
/// zeros). Deterministic: re-quantizing the same input yields identical
/// bytes and scales. Returns `(q, scales)` with `scales.len() == rows`.
pub fn quantize_rows_i8(w: &[f32], rows: usize, cols: usize) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(w.len(), rows * cols);
    let mut q = vec![0i8; rows * cols];
    let mut scales = vec![1.0f32; rows];
    for r in 0..rows {
        let src = &w[r * cols..(r + 1) * cols];
        let maxabs = src.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        if maxabs == 0.0 {
            continue; // q stays 0, scale stays 1.0
        }
        let scale = maxabs / QUANT_I8_MAX;
        scales[r] = scale;
        let dst = &mut q[r * cols..(r + 1) * cols];
        for (d, x) in dst.iter_mut().zip(src) {
            *d = (x / scale).round().clamp(-QUANT_I8_MAX, QUANT_I8_MAX) as i8;
        }
    }
    (q, scales)
}

/// Inverse of [`quantize_rows_i8`]: `w[r,c] = q[r,c] · scale[r]`. The
/// round-trip error per element is bounded by `scale[r] / 2`.
pub fn dequantize_rows_i8(q: &[i8], scales: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    assert_eq!(q.len(), rows * cols);
    assert_eq!(scales.len(), rows);
    let mut w = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let s = scales[r];
        for (d, &x) in w[r * cols..(r + 1) * cols].iter_mut().zip(&q[r * cols..(r + 1) * cols]) {
            *d = x as f32 * s;
        }
    }
    w
}

/// Full-tile int8 microkernel: B is `[k, n]` int8 with one f32 scale per
/// B row (= per reduction index), folded into the broadcast A value so the
/// inner loop is a pure i8→f32 convert + multiply-add over a contiguous
/// panel. `y[i,j] = Σ_kk (a[i,kk]·scales[kk]) · q[kk,j]` in ascending kk —
/// a deterministic, pinned reduction order (bit-identical at any thread
/// count), though *not* bit-equal to dequantize-then-f32-GEMM, which
/// associates the scale with B instead.
#[inline]
#[allow(clippy::too_many_arguments)]
fn gemm_q8_kernel_full(
    a: &[f32],
    q: &[i8],
    scales: &[f32],
    k: usize,
    n: usize,
    i: usize,
    j: usize,
    r0: usize,
    crows: &mut [f32],
) {
    let mut acc = [[0.0f32; GEMM_NR]; GEMM_MR];
    let arows: [&[f32]; GEMM_MR] = [
        &a[i * k..(i + 1) * k],
        &a[(i + 1) * k..(i + 2) * k],
        &a[(i + 2) * k..(i + 3) * k],
        &a[(i + 3) * k..(i + 4) * k],
    ];
    for kk in 0..k {
        let qp = &q[kk * n + j..kk * n + j + GEMM_NR];
        let s = scales[kk];
        for (accr, arow) in acc.iter_mut().zip(arows) {
            let av = arow[kk] * s;
            for (slot, &qv) in accr.iter_mut().zip(qp) {
                *slot += av * qv as f32;
            }
        }
    }
    for (rr, accr) in acc.iter().enumerate() {
        crows[(r0 + rr) * n + j..(r0 + rr) * n + j + GEMM_NR].copy_from_slice(accr);
    }
}

/// Edge int8 microkernel (partial tiles) — same reduction order as
/// [`gemm_q8_kernel_full`] with runtime trip counts.
#[inline]
#[allow(clippy::too_many_arguments)]
fn gemm_q8_kernel_edge(
    a: &[f32],
    q: &[i8],
    scales: &[f32],
    k: usize,
    n: usize,
    i: usize,
    j: usize,
    mr: usize,
    nr: usize,
    r0: usize,
    crows: &mut [f32],
) {
    let mut acc = [[0.0f32; GEMM_NR]; GEMM_MR];
    for kk in 0..k {
        let qp = &q[kk * n + j..kk * n + j + nr];
        let s = scales[kk];
        for (rr, accr) in acc.iter_mut().enumerate().take(mr) {
            let av = a[(i + rr) * k + kk] * s;
            for (slot, &qv) in accr.iter_mut().zip(qp) {
                *slot += av * qv as f32;
            }
        }
    }
    for (rr, accr) in acc.iter().enumerate().take(mr) {
        crows[(r0 + rr) * n + j..(r0 + rr) * n + j + nr].copy_from_slice(&accr[..nr]);
    }
}

/// C[M,N] = A[M,K] @ dequant(Q[K,N]) with per-K-row scales, tiled and
/// row-parallel like [`matmul_blocked_with`]. The scale is folded into the
/// activation broadcast (see [`gemm_q8_kernel_full`]); the reduction order
/// is ascending kk per element, so output is bit-identical at any thread
/// count. `scales.len()` must be `k`.
pub fn matmul_q8_with(
    a: &[f32],
    q: &[i8],
    scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(q.len(), k * n);
    assert_eq!(scales.len(), k);
    let mut c = vec![0.0f32; m * n];
    if n == 0 || m == 0 {
        return c;
    }
    let row_block = |i0: usize, crows: &mut [f32]| {
        let rows = crows.len() / n;
        let mut r = 0;
        while r < rows {
            let mr = GEMM_MR.min(rows - r);
            let mut j = 0;
            while j < n {
                let nr = GEMM_NR.min(n - j);
                if mr == GEMM_MR && nr == GEMM_NR {
                    gemm_q8_kernel_full(a, q, scales, k, n, i0 + r, j, r, crows);
                } else {
                    gemm_q8_kernel_edge(a, q, scales, k, n, i0 + r, j, mr, nr, r, crows);
                }
                j += nr;
            }
            r += mr;
        }
    };
    parallel::par_row_chunks_mut(threads, &mut c, n, row_block);
    c
}

/// Pearson correlation matrix between rows of X [p, t] and rows of Y [q, t]
/// (auto-parallel over output rows; the ZipIt path builds O((|C|·m)²)
/// correlations through this, the dominant merge-time cost).
pub fn corr_matrix(x: &[f32], y: &[f32], p: usize, q: usize, t: usize) -> Vec<f32> {
    let threads = parallel::default_threads();
    let auto = if p * q * t >= parallel::PAR_AUTO_WORK { threads } else { 1 };
    corr_matrix_with(x, y, p, q, t, auto)
}

/// [`corr_matrix`] with an explicit worker count. Output rows are disjoint
/// and each entry is one `dot(xi, yj) * xn[i] * yn[j]` — identical operand
/// order at any thread count, so results are bit-identical to serial.
pub fn corr_matrix_with(
    x: &[f32],
    y: &[f32],
    p: usize,
    q: usize,
    t: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), p * t);
    assert_eq!(y.len(), q * t);
    let norm = |v: &[f32]| -> (Vec<f32>, Vec<f32>) {
        let rows = v.len() / t;
        let mut centered = vec![0.0f32; v.len()];
        let mut inv_norm = vec![0.0f32; rows];
        for r in 0..rows {
            let row = &v[r * t..(r + 1) * t];
            let mean = row.iter().sum::<f32>() / t as f32;
            let dst = &mut centered[r * t..(r + 1) * t];
            for (d, s) in dst.iter_mut().zip(row) {
                *d = s - mean;
            }
            let nrm = dot(dst, dst).sqrt();
            inv_norm[r] = if nrm > 1e-12 { 1.0 / nrm } else { 0.0 };
        }
        (centered, inv_norm)
    };
    let (xc, xn) = norm(x);
    let (yc, yn) = norm(y);
    let mut c = vec![0.0f32; p * q];
    if q == 0 || p == 0 {
        return c;
    }
    let fill = |i0: usize, crows: &mut [f32]| {
        for (off, crow) in crows.chunks_mut(q).enumerate() {
            let i = i0 + off;
            let xi = &xc[i * t..(i + 1) * t];
            for (j, slot) in crow.iter_mut().enumerate() {
                let yj = &yc[j * t..(j + 1) * t];
                *slot = dot(xi, yj) * xn[i] * yn[j];
            }
        }
    };
    parallel::par_row_chunks_mut(threads, &mut c, q, fill);
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn index_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.index(1).data(), &[4., 5., 6.]);
        let mut t2 = t.clone();
        t2.set_index(0, &Tensor::from_vec(vec![7., 8., 9.]));
        assert_eq!(t2.row(0), &[7., 8., 9.]);
    }

    #[test]
    fn weighted_sum_merging() {
        let a = Tensor::from_vec(vec![1.0, 0.0]);
        let b = Tensor::from_vec(vec![0.0, 1.0]);
        let m = weighted_sum(&[&a, &b], &[0.25, 0.75]).unwrap();
        assert_eq!(m.data(), &[0.25, 0.75]);
    }

    #[test]
    fn distances() {
        let a = [1.0, 0.0];
        let b = [0.0, 1.0];
        assert!((l2_dist(&a, &b) - 2f32.sqrt()).abs() < 1e-6);
        assert!(cosine_sim(&a, &b).abs() < 1e-6);
        assert!((cosine_dist(&a, &a)).abs() < 1e-6);
    }

    #[test]
    fn gather_rows_packs_and_repeats() {
        let src = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3 rows of len 2
        assert_eq!(gather_rows(&src, 2, &[2, 0, 2]), vec![5.0, 6.0, 1.0, 2.0, 5.0, 6.0]);
        assert!(gather_rows(&src, 2, &[]).is_empty());
    }

    #[test]
    fn matmul_small() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let c = matmul(&[1., 2., 3., 4.], &[5., 6., 7., 8.], 2, 2, 2);
        assert_eq!(c, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn blocked_parallel_matmul_is_bit_identical() {
        let mut rng = crate::util::Rng::new(77);
        let (m, k, n) = (13, 31, 157); // odd sizes: edge tiles in both dims
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let reference = matmul_reference(&a, &b, m, k, n);
        let serial = matmul(&a, &b, m, k, n);
        assert!(
            reference.iter().zip(&serial).all(|(x, y)| x.to_bits() == y.to_bits()),
            "tiled serial != scalar reference"
        );
        for threads in [1usize, 2, 3, 8] {
            let par = matmul_blocked_with(&a, &b, m, k, n, threads);
            let same = reference
                .iter()
                .zip(&par)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "threads={threads}");
        }
    }

    #[test]
    fn tiled_matmul_matches_reference_with_zero_rows() {
        // exercise the reference's zero-skip equivalence: whole A rows and
        // scattered elements are exactly 0.0
        let mut rng = crate::util::Rng::new(79);
        let (m, k, n) = (9, 21, 39);
        let mut a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        for v in a.iter_mut().step_by(3) {
            *v = 0.0;
        }
        for v in a[2 * k..3 * k].iter_mut() {
            *v = 0.0;
        }
        let reference = matmul_reference(&a, &b, m, k, n);
        let tiled = matmul(&a, &b, m, k, n);
        assert!(reference.iter().zip(&tiled).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn quantize_roundtrip_bound_and_determinism() {
        let mut rng = crate::util::Rng::new(80);
        let (rows, cols) = (7, 33);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let (q, scales) = quantize_rows_i8(&w, rows, cols);
        let (q2, scales2) = quantize_rows_i8(&w, rows, cols);
        assert_eq!(q, q2, "re-quantization must be deterministic");
        assert!(scales.iter().zip(&scales2).all(|(a, b)| a.to_bits() == b.to_bits()));
        let dq = dequantize_rows_i8(&q, &scales, rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                let err = (w[r * cols + c] - dq[r * cols + c]).abs();
                assert!(err <= scales[r] * 0.5 + 1e-7, "row {r} col {c}: err {err}");
            }
        }
    }

    #[test]
    fn q8_matmul_is_thread_bit_identical_and_close_to_f32() {
        let mut rng = crate::util::Rng::new(81);
        let (m, k, n) = (6, 19, 45);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let (q, scales) = quantize_rows_i8(&w, k, n);
        let serial = matmul_q8_with(&a, &q, &scales, m, k, n, 1);
        for threads in [2usize, 3, 8] {
            let par = matmul_q8_with(&a, &q, &scales, m, k, n, threads);
            assert!(
                serial.iter().zip(&par).all(|(x, y)| x.to_bits() == y.to_bits()),
                "threads={threads}"
            );
        }
        // quantized result approximates the f32 GEMM on the dequantized B
        let exact = matmul_reference(&a, &w, m, k, n);
        for (got, want) in serial.iter().zip(&exact) {
            assert!((got - want).abs() < 0.25, "got {got}, want {want}");
        }
    }

    #[test]
    fn parallel_corr_matrix_is_bit_identical() {
        let mut rng = crate::util::Rng::new(78);
        let (p, q, t) = (9, 7, 33);
        let x: Vec<f32> = (0..p * t).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..q * t).map(|_| rng.normal() as f32).collect();
        let serial = corr_matrix_with(&x, &y, p, q, t, 1);
        for threads in [2usize, 3, 5] {
            let par = corr_matrix_with(&x, &y, p, q, t, threads);
            let same = serial
                .iter()
                .zip(&par)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "threads={threads}");
        }
    }

    #[test]
    fn corr_perfect() {
        // row correlated with itself = 1, with its negation = -1
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let y = [-1.0f32, -2.0, -3.0, -4.0];
        let c = corr_matrix(&x, &y, 1, 1, 4);
        assert!((c[0] + 1.0).abs() < 1e-5);
        let c2 = corr_matrix(&x, &x, 1, 1, 4);
        assert!((c2[0] - 1.0).abs() < 1e-5);
    }
}
