"""L1 correctness: Pallas kernel vs the pure-jnp oracle.

The CORE correctness signal of the build path: the kernel that ships inside
every lowered HLO artifact must match `ref.moe_ffn_ref` bit-for-tolerance,
across shapes and dtypes (swept with Hypothesis).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.moe_ffn import moe_ffn, mxu_flops, vmem_bytes


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


def make_inputs(seed, n, c, d, m, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return (
        rand(ks[0], (n, c, d), dtype),
        rand(ks[1], (n, d, m), dtype) * 0.1,
        rand(ks[2], (n, d, m), dtype) * 0.1,
        rand(ks[3], (n, m, d), dtype) * 0.1,
    )


class TestKernelVsRef:
    def test_basic_shapes(self):
        x, wg, wu, wd = make_inputs(0, 4, 64, 32, 48)
        out = moe_ffn(x, wg, wu, wd, block_c=32)
        expect = ref.moe_ffn_ref(x, wg, wu, wd)
        np.testing.assert_allclose(out, expect, atol=1e-5, rtol=1e-5)

    def test_single_block(self):
        x, wg, wu, wd = make_inputs(1, 2, 16, 8, 8)
        out = moe_ffn(x, wg, wu, wd, block_c=16)
        np.testing.assert_allclose(out, ref.moe_ffn_ref(x, wg, wu, wd), atol=1e-5)

    def test_zero_rows_stay_zero(self):
        # capacity padding relies on silu(0)*0 @ W == 0
        x, wg, wu, wd = make_inputs(2, 2, 32, 8, 8)
        x = x.at[:, 16:, :].set(0.0)
        out = moe_ffn(x, wg, wu, wd, block_c=16)
        np.testing.assert_allclose(out[:, 16:, :], 0.0, atol=1e-7)

    def test_rejects_bad_block(self):
        x, wg, wu, wd = make_inputs(3, 2, 20, 8, 8)
        with pytest.raises(ValueError):
            moe_ffn(x, wg, wu, wd, block_c=16)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.sampled_from([1, 2, 4, 8]),
        blocks=st.integers(1, 3),
        block_c=st.sampled_from([8, 16, 32]),
        d=st.sampled_from([4, 16, 96]),
        m=st.sampled_from([8, 64, 96]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shape_sweep(self, n, blocks, block_c, d, m, seed):
        c = blocks * block_c
        x, wg, wu, wd = make_inputs(seed, n, c, d, m)
        out = moe_ffn(x, wg, wu, wd, block_c=block_c)
        expect = ref.moe_ffn_ref(x, wg, wu, wd)
        np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)

    @settings(max_examples=6, deadline=None)
    @given(
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_dtype_sweep(self, dtype, seed):
        x, wg, wu, wd = make_inputs(seed, 2, 32, 16, 16, dtype)
        out = moe_ffn(x, wg, wu, wd, block_c=16)
        expect = ref.moe_ffn_ref(x, wg, wu, wd)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            atol=tol, rtol=tol,
        )
        assert out.dtype == dtype


class TestAnalytics:
    def test_vmem_budget_of_shipped_shapes(self):
        # the shipped kernels must fit VMEM with room for double-buffering
        assert vmem_bytes(32, 96, 192) < 2 * 1024 * 1024

    def test_mxu_flops_formula(self):
        # one expert, one token: 2 GEMMs d*m + 1 GEMM m*d, 2 flops per MAC
        assert mxu_flops(1, 1, 4, 8) == 2 * 4 * 8 * 2 + 2 * 8 * 4


class TestSwiglu:
    def test_swiglu_matches_dense_path(self):
        k = jax.random.PRNGKey(7)
        ks = jax.random.split(k, 4)
        x = rand(ks[0], (10, 8))
        wg = rand(ks[1], (8, 12)) * 0.1
        wu = rand(ks[2], (8, 12)) * 0.1
        wd = rand(ks[3], (12, 8)) * 0.1
        one = ref.swiglu(x, wg, wu, wd)
        dense = ref.expert_ffn_dense(x, wg[None], wu[None], wd[None])[:, 0]
        np.testing.assert_allclose(one, dense, atol=1e-6)
