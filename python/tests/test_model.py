"""L2 correctness: routing, dispatch/combine, forwards, calibration stats."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref

TINY = M.ModelCfg(
    name="tiny", n_layer=2, d=32, m=24, n_exp=4, k=2, heads=2,
    vocab=64, t_max=64, block_c=8,
)
TINY_SHARED = M.ModelCfg(
    name="tinysh", n_layer=2, d=32, m=16, n_exp=4, k=2, heads=2,
    vocab=64, t_max=64, shared=True, m_shared=24, block_c=8,
)


@pytest.fixture(scope="module")
def params():
    return M.init_params(TINY, 0)


class TestRouting:
    def test_route_topk_matches_lax_topk(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (50, 8))
        idx, probs = ref.route_topk(logits, 2)
        vals_l, idx_l = jax.lax.top_k(logits, 2)
        np.testing.assert_array_equal(idx, idx_l)
        np.testing.assert_allclose(probs, jax.nn.softmax(vals_l, -1), atol=1e-6)

    def test_mask_excludes_experts(self):
        logits = jax.random.normal(jax.random.PRNGKey(1), (50, 8))
        mask = jnp.zeros(8).at[3].set(-1e30).at[5].set(-1e30)
        idx, _ = ref.route_topk(logits, 2, mask)
        assert not np.isin(np.asarray(idx), [3, 5]).any()

    def test_probs_sum_to_one(self):
        logits = jax.random.normal(jax.random.PRNGKey(2), (20, 8))
        _, probs = ref.route_topk(logits, 2)
        np.testing.assert_allclose(probs.sum(-1), 1.0, atol=1e-6)


class TestDispatch:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), t=st.sampled_from([16, 40, 64]))
    def test_dispatch_combine_roundtrip_vs_dense(self, seed, t):
        """With generous capacity (no drops) the dispatch path must equal
        the dense Eq. (1) computation exactly."""
        ks = jax.random.split(jax.random.PRNGKey(seed), 6)
        n, d, m, k = 4, 16, 12, 2
        x = jax.random.normal(ks[0], (t, d))
        wr = jax.random.normal(ks[1], (d, n)) * 0.5
        wg = jax.random.normal(ks[2], (n, d, m)) * 0.2
        wu = jax.random.normal(ks[3], (n, d, m)) * 0.2
        wd = jax.random.normal(ks[4], (n, m, d)) * 0.2
        dense = ref.moe_layer_dense(x, wr, wg, wu, wd, k)
        # dispatch with capacity = all slots (no drop possible)
        logits = x @ wr
        idx, probs = ref.route_topk(logits, k)
        cap = t * k
        xd, e_flat, p_flat, keep = M.dispatch(x, idx, probs, n, cap)
        out_d = ref.moe_ffn_ref(xd, wg, wu, wd)
        y = M.combine(out_d, e_flat, p_flat, keep, probs)
        np.testing.assert_allclose(y, dense, atol=1e-4, rtol=1e-4)

    def test_capacity_drops_excess_tokens(self):
        # all tokens to expert 0 with capacity 2: only 2 slots filled
        x = jnp.ones((5, 3))
        idx = jnp.zeros((5, 1), jnp.int32)
        probs = jnp.ones((5, 1))
        xd, _, p_flat, keep = M.dispatch(x, idx, probs, 2, 2)
        assert int(keep.sum()) == 2
        assert float(jnp.abs(xd[0, 2:]).sum()) == 0.0
        assert float(jnp.abs(xd[1]).sum()) == 0.0
        assert int(p_flat.max()) == 4


class TestForward:
    def test_pallas_and_ref_paths_agree(self, params):
        ids = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, TINY.vocab)
        mask = jnp.zeros((TINY.n_layer, TINY.n_exp))
        a = M.forward_logits(TINY, params, ids, mask, use_pallas=True)
        b = M.forward_logits(TINY, params, ids, mask, use_pallas=False)
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)
        assert a.shape == (2, 16, TINY.vocab)

    def test_mask_reroutes_like_pruning(self, params):
        ids = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, TINY.vocab)
        mask0 = jnp.zeros((TINY.n_layer, TINY.n_exp))
        mask_pruned = mask0.at[:, 0].set(-1e30)
        a = M.forward_logits(TINY, params, ids, mask0, use_pallas=False)
        b = M.forward_logits(TINY, params, ids, mask_pruned, use_pallas=False)
        assert not np.allclose(a, b), "pruning an expert must change outputs"

    def test_compact_variant_equals_duplicated_full(self, params):
        """The central runtime identity: merging via duplicated slots on the
        n-expert executable == the true r-expert compact executable.

        Uses a generous capacity factor so no path drops tokens — the
        identity under capacity pressure is policy, not math (the compact
        variant ships 2x headroom; see model.moe_block)."""
        import dataclasses
        cfg = dataclasses.replace(TINY, cap_factor=8.0)
        ids = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0, cfg.vocab)
        mask = jnp.zeros((cfg.n_layer, cfg.n_exp))
        # merge plan: {0,1} -> A, {2} -> B, {3} -> C (r=3), same every layer
        groups = [[0, 1], [2], [3]]
        full = dict(params)
        r = len(groups)
        remap_row = [0, 0, 1, 2]
        for l in range(cfg.n_layer):
            pre = f"layer{l:02d}."
            for wkey in ("exp.wg", "exp.wu", "exp.wd"):
                w = params[pre + wkey]
                merged = [w[jnp.asarray(g)].mean(axis=0) for g in groups]
                # duplicated layout
                dup = w
                for gi, g in enumerate(groups):
                    for e in g:
                        dup = dup.at[e].set(merged[gi])
                full[pre + wkey] = dup
        a = M.forward_logits(cfg, full, ids, mask, use_pallas=False)
        compact = {
            k: (jnp.stack([v[[0, 2, 3][s]] for s in range(r)]) if ".exp." in k else v)
            for k, v in full.items()
        }
        remap = jnp.asarray([remap_row] * cfg.n_layer, jnp.int32)
        b = M.forward_logits_compact(cfg, compact, ids, mask, remap, r, use_pallas=False)
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)

    def test_shared_expert_path(self):
        p = M.init_params(TINY_SHARED, 1)
        ids = jax.random.randint(jax.random.PRNGKey(6), (2, 16), 0, 64)
        mask = jnp.zeros((2, 4))
        out = M.forward_logits(TINY_SHARED, p, ids, mask, use_pallas=False)
        assert out.shape == (2, 16, 64)
        # zeroing the shared expert changes the output
        p2 = dict(p)
        for l in range(2):
            p2[f"layer{l:02d}.shared.wd"] = jnp.zeros_like(p[f"layer{l:02d}.shared.wd"])
        out2 = M.forward_logits(TINY_SHARED, p2, ids, mask, use_pallas=False)
        assert not np.allclose(out, out2)


class TestCalib:
    def test_stat_shapes_and_consistency(self, params):
        ids = jax.random.randint(jax.random.PRNGKey(7), (2, 32), 0, TINY.vocab)
        stats = M.forward_calib(TINY, params, ids, t_sub=16, t_act=8)
        mean_out, counts, probs_sum, gate_sum, rl, raw, act, hid = stats
        L, n, d, m = TINY.n_layer, TINY.n_exp, TINY.d, TINY.m
        assert mean_out.shape == (L, n, d)
        assert counts.shape == (L, n)
        assert rl.shape == (L, 16, n)
        assert raw.shape == (L, n, 16, d)
        assert act.shape == (L, n, 8, m)
        assert hid.shape == (L, 16, d)
        tok = 2 * 32
        # each token picks exactly k experts
        np.testing.assert_allclose(counts.sum(-1), tok * TINY.k, atol=1e-4)
        # full-softmax scores sum to the token count
        np.testing.assert_allclose(probs_sum.sum(-1), tok, atol=1e-3)
        # gate weights sum to the token count (softmax over k)
        np.testing.assert_allclose(gate_sum.sum(-1), tok, atol=1e-3)

    def test_raw_outputs_match_direct_expert_eval(self, params):
        """raw_sub[l, e, s] must equal E_e(hid_sub[l, s]) — the invariant
        O-prune's replay relies on."""
        ids = jax.random.randint(jax.random.PRNGKey(8), (2, 32), 0, TINY.vocab)
        stats = M.forward_calib(TINY, params, ids, t_sub=16, t_act=8)
        _, _, _, _, _, raw, _, hid = stats
        for l in range(TINY.n_layer):
            pre = f"layer{l:02d}."
            outs = ref.expert_ffn_dense(
                hid[l], params[pre + "exp.wg"], params[pre + "exp.wu"],
                params[pre + "exp.wd"],
            )  # [t_sub, n, d]
            np.testing.assert_allclose(
                raw[l], outs.transpose(1, 0, 2), atol=1e-4, rtol=1e-4
            )


class TestTraining:
    def test_loss_decreases_on_tiny_corpus(self):
        cfg = TINY
        p = M.init_params(cfg, 3)
        opt = M.adam_init(p)
        step = M.make_train_step(cfg)
        ids = jax.random.randint(jax.random.PRNGKey(9), (4, 32), 0, cfg.vocab)
        first = None
        for i in range(30):
            p, opt, loss, ce = step(p, opt, ids, 3e-3)
            if i == 0:
                first = float(ce)
        assert float(ce) < first * 0.8, f"{first} -> {float(ce)}"
