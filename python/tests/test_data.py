"""Synthetic-language substrate: determinism, formats, benchmark sanity."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data as D


@pytest.fixture(scope="module")
def kb():
    return D.KnowledgeBase.build()


@pytest.fixture(scope="module")
def corpus(kb):
    return D.CorpusGen(kb)


@pytest.fixture(scope="module")
def bench(kb, corpus):
    return D.BenchmarkGen(kb, corpus)


class TestVocab:
    def test_ranges_disjoint_and_in_vocab(self):
        ranges = [D.SUBJ, D.REL, D.OBJ, D.DIGIT, D.KEY, D.VAL, D.MED_SUBJ,
                  D.MED_OBJ, D.FILLER]
        flat = sorted(ranges)
        for (a1, b1), (a2, _) in zip(flat, flat[1:]):
            assert b1 <= a2, f"overlap: {(a1, b1)} vs {a2}"
        assert all(b <= D.VOCAB_SIZE for _, b in ranges)


class TestKnowledgeBase:
    def test_deterministic(self, kb):
        kb2 = D.KnowledgeBase.build()
        assert kb.easy == kb2.easy and kb.hard == kb2.hard and kb.med == kb2.med

    def test_tiers_cover_all_subjects(self, kb):
        n_subj = D.SUBJ[1] - D.SUBJ[0]
        n_rel = D.REL[1] - D.REL[0]
        assert len(kb.easy) + len(kb.hard) == n_subj * n_rel
        assert len(kb.med) == (D.MED_SUBJ[1] - D.MED_SUBJ[0]) * 4

    def test_hop_resolves(self, kb):
        hits = 0
        for s in range(D.SUBJ[0], D.SUBJ[1]):
            if kb.hop(s, D.REL[0], D.REL[1] - 1) is not None:
                hits += 1
        assert hits == D.SUBJ[1] - D.SUBJ[0], "two-hop chains must always resolve"


class TestCorpus:
    @pytest.mark.parametrize("dom", ["general", "math", "code", "med"])
    def test_streams_deterministic_and_in_vocab(self, corpus, dom):
        a = corpus.stream(dom, 5, 500)
        b = corpus.stream(dom, 5, 500)
        np.testing.assert_array_equal(a, b)
        assert a.min() >= 0 and a.max() < D.VOCAB_SIZE
        assert len(a) == 500

    def test_streams_differ_by_seed(self, corpus):
        a = corpus.stream("general", 1, 500)
        b = corpus.stream("general", 2, 500)
        assert not np.array_equal(a, b)

    def test_math_statements_are_valid(self, corpus):
        toks = corpus.stream("math", 3, 600).tolist()
        i = 0
        checked = 0
        while i + 5 < len(toks):
            if toks[i + 1] in (D.OP_ADD, D.OP_MUL) and toks[i + 3] == D.OP_EQ:
                a = toks[i] - D.DIGIT[0]
                b = toks[i + 2] - D.DIGIT[0]
                c = toks[i + 4] - D.DIGIT[0]
                expect = (a + b) % D.MOD if toks[i + 1] == D.OP_ADD else (a * b) % D.MOD
                assert c == expect
                checked += 1
                i += 6
            else:
                i += 1
        assert checked >= 50

    def test_domains_have_distinct_token_distributions(self, corpus):
        gen = set(corpus.stream("general", 7, 800).tolist())
        med = set(corpus.stream("med", 7, 800).tolist())
        med_only = range(D.MED_SUBJ[0], D.MED_OBJ[1])
        assert any(t in med for t in med_only)
        assert not any(t in gen for t in med_only), "med facts leak into general"


class TestBenchmarks:
    @pytest.mark.parametrize("task", D.BenchmarkGen.TASKS)
    def test_generation_and_answers(self, bench, task):
        items = bench.dataset(task, 32, seed=1)
        assert len(items) == 32
        n_choices = len(items[0].choices)
        assert n_choices in (2, 4)
        for it in items:
            assert 0 <= it.answer < n_choices
            assert len(set(tuple(c) for c in it.choices)) == n_choices, "dup choices"
            assert all(0 <= t < D.VOCAB_SIZE for t in it.prompt)

    def test_fact_answers_are_correct(self, bench, kb):
        for it in bench.dataset("arc_e", 20, seed=2):
            s, r = it.prompt[1], it.prompt[2]
            assert it.choices[it.answer][0] == kb.easy[(s, r)]

    def test_binary_tasks_are_balanced(self, bench):
        for task in ["boolq", "rte", "wino"]:
            items = bench.dataset(task, 200, seed=3)
            frac = sum(i.answer for i in items) / len(items)
            assert 0.35 < frac < 0.65, f"{task} answer balance {frac}"

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_dataset_determinism(self, bench, seed):
        a = bench.dataset("mmlu", 5, seed)
        b = bench.dataset("mmlu", 5, seed)
        for x, y in zip(a, b):
            assert x.prompt == y.prompt and x.choices == y.choices


class TestSerialization:
    def test_benchmark_roundtrip(self, bench, tmp_path):
        items = bench.dataset("obqa", 16, seed=4)
        path = str(tmp_path / "obqa.bin")
        D.write_benchmark(path, items)
        back = D.read_benchmark(path)
        assert len(back) == 16
        for a, b in zip(items, back):
            assert a.prompt == b.prompt
            assert a.choices == b.choices
            assert a.answer == b.answer

    def test_tokens_roundtrip(self, corpus, tmp_path):
        toks = corpus.stream("code", 9, 300)
        path = str(tmp_path / "t.bin")
        D.write_tokens(path, toks)
        np.testing.assert_array_equal(D.read_tokens(path), toks)
