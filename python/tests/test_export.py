"""Weights serialisation + AOT interface contracts."""

import numpy as np
import pytest

from compile import model as M
from compile.export import load_weights, save_weights

TINY = M.ModelCfg(
    name="tiny", n_layer=2, d=16, m=12, n_exp=4, k=2, heads=2,
    vocab=32, t_max=32, block_c=4,
)


class TestWeightsIO:
    def test_roundtrip(self, tmp_path):
        p = {k: np.asarray(v) for k, v in M.init_params(TINY, 0).items()}
        path = str(tmp_path / "w.hcwt")
        save_weights(path, p)
        back = load_weights(path)
        assert sorted(back) == sorted(p)
        for k in p:
            np.testing.assert_array_equal(back[k], p[k].astype(np.float32))

    def test_order_is_sorted_names(self, tmp_path):
        """The HLO parameter order contract: tensors are stored sorted."""
        p = {"b": np.ones(2, np.float32), "a": np.zeros(3, np.float32)}
        path = str(tmp_path / "o.hcwt")
        save_weights(path, p)
        raw = open(path, "rb").read()
        assert raw.index(b"a") < raw.index(b"b")


class TestParamLayout:
    def test_param_names_stable(self):
        names = M.param_names(TINY)
        assert names == sorted(names)
        assert "embed" in names and "layer00.exp.wg" in names

    def test_shared_model_has_shared_tensors(self):
        cfg = M.ModelCfg(
            name="sh", n_layer=1, d=8, m=8, n_exp=2, heads=2, vocab=16,
            t_max=16, shared=True, m_shared=12, block_c=4,
        )
        names = M.param_names(cfg)
        assert "layer00.shared.wg" in names

    def test_compact_params_slices_experts_only(self):
        p = M.init_params(TINY, 0)
        c = M.compact_params(p, 2)
        assert c["layer00.exp.wg"].shape[0] == 2
        assert c["embed"].shape == p["embed"].shape

    def test_cfg_kv_roundtrip_keys(self):
        kv = TINY.to_kv()
        for key in ("n_layer", "d", "m", "n_exp", "k", "vocab", "cap_factor"):
            assert f"{key} = " in kv
