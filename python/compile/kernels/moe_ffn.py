"""L1 — Pallas grouped expert-FFN kernel.

This is the compute hot-spot of every SMoE model the paper studies: the
SwiGLU expert FFN of Eq. (2),

    E(x) = (silu(x @ W_gate) * (x @ W_up)) @ W_down

applied independently by each expert to its capacity-dispatched token block.
The kernel is written for a TPU-shaped machine (see DESIGN.md
§Hardware-Adaptation): the grid iterates (expert, token-block); BlockSpecs
stage one expert's weight tiles and one token block HBM→VMEM per program
instance; the three GEMMs target the MXU.  On this CPU-only sandbox it runs
under ``interpret=True`` (real-TPU lowering emits Mosaic custom-calls the CPU
PJRT plugin cannot execute); numerics are validated against
``kernels.ref.moe_ffn_ref`` in pytest.

VMEM footprint per program instance (f32):
    x block   Cb*d
    weights   3*d*m          (W_gate, W_up, W_down tiles)
    h scratch Cb*m
    out       Cb*d
With the shipped shapes (Cb=64, d=128, m<=256) this is ~113-140 KiB, far
below the ~16 MiB VMEM budget — the schedule leaves room for double
buffering of the next token block while the MXU drains the current one.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    """One (expert, token-block) program instance.

    Refs carry a leading singleton expert axis from the BlockSpecs.
    """
    x = x_ref[0]  # [Cb, d]
    g = jnp.dot(x, wg_ref[0])  # [Cb, m] — MXU GEMM 1
    u = jnp.dot(x, wu_ref[0])  # [Cb, m] — MXU GEMM 2
    h = jax.nn.silu(g) * u     # VPU elementwise
    o_ref[0] = jnp.dot(h, wd_ref[0])  # [Cb, d] — MXU GEMM 3


@functools.partial(jax.jit, static_argnames=("block_c",))
def moe_ffn(x_dispatch, w_gate, w_up, w_down, *, block_c: int = 64):
    """Grouped expert FFN over dispatched tokens.

    Args:
      x_dispatch: [n, C, d] tokens gathered per expert (zero-padded slots).
      w_gate, w_up: [n, d, m] stacked expert weights.
      w_down: [n, m, d].
      block_c: token-block size per program instance; must divide C.

    Returns:
      [n, C, d] expert outputs (zero rows stay zero: silu(0)*0 @ W = 0).
    """
    n, c, d = x_dispatch.shape
    m = w_gate.shape[-1]
    if c % block_c != 0:
        raise ValueError(f"capacity {c} not divisible by block_c {block_c}")
    grid = (n, c // block_c)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda e, t: (e, t, 0)),
            pl.BlockSpec((1, d, m), lambda e, t: (e, 0, 0)),
            pl.BlockSpec((1, d, m), lambda e, t: (e, 0, 0)),
            pl.BlockSpec((1, m, d), lambda e, t: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, d), lambda e, t: (e, t, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, d), x_dispatch.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x_dispatch, w_gate, w_up, w_down)


def vmem_bytes(block_c: int, d: int, m: int, dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one program instance (for DESIGN §Perf)."""
    return dtype_bytes * (block_c * d + 3 * d * m + block_c * m + block_c * d)


def mxu_flops(n: int, c: int, d: int, m: int) -> int:
    """Total MXU FLOPs of one grouped-FFN invocation (2*M*N*K per GEMM)."""
    return n * c * (2 * d * m * 2 + 2 * m * d)
