"""Pure-jnp correctness oracles for the L1 Pallas kernel and the MoE layer.

These are the ground-truth implementations everything else is tested
against:

* ``moe_ffn_ref``      — grouped expert FFN, same contract as
                         ``moe_ffn.moe_ffn`` (dispatched [n, C, d] input).
* ``expert_ffn_dense`` — every expert applied to every token (used by the
                         calibration pass and by tests).
* ``moe_layer_dense``  — the full SMoE layer of Eq. (1) computed densely
                         (no capacity dispatch, no token dropping); the
                         dispatch-based layer must match it whenever no
                         token exceeds expert capacity.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def swiglu(x, wg, wu, wd):
    """Eq. (2): (silu(x Wg) * (x Wu)) Wd for a single expert."""
    return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd


def moe_ffn_ref(x_dispatch, w_gate, w_up, w_down):
    """[n, C, d] -> [n, C, d]; per-expert SwiGLU via einsum (no Pallas)."""
    g = jnp.einsum("ncd,ndm->ncm", x_dispatch, w_gate)
    u = jnp.einsum("ncd,ndm->ncm", x_dispatch, w_up)
    h = jax.nn.silu(g) * u
    return jnp.einsum("ncm,nmd->ncd", h, w_down)


def expert_ffn_dense(x, w_gate, w_up, w_down):
    """Every expert on every token: [T, d] x [n, d, m] -> [T, n, d]."""
    g = jnp.einsum("td,ndm->tnm", x, w_gate)
    u = jnp.einsum("td,ndm->tnm", x, w_up)
    h = jax.nn.silu(g) * u
    return jnp.einsum("tnm,nmd->tnd", h, w_down)


def expert_act_dense(x, w_gate, w_up):
    """Intermediate activations (pre-W_down), Appendix B.2 'act' features:
    [T, d] -> [T, n, m]."""
    g = jnp.einsum("td,ndm->tnm", x, w_gate)
    u = jnp.einsum("td,ndm->tnm", x, w_up)
    return jax.nn.silu(g) * u


def route_topk(router_logits, k, mask=None):
    """Eq. (3): softmax over the top-k router logits.

    Returns (indices [T, k], probs [T, k]). ``mask`` is an additive [n]
    vector (0 = keep, -1e30 = pruned expert).

    Implemented as k rounds of argmax + re-masking instead of
    ``jax.lax.top_k``: jax >= 0.7 lowers top_k to the ``topk`` HLO
    instruction whose text form (``largest=true``) the xla_extension 0.5.1
    parser rejects; argmax lowers to plain reduces that round-trip fine.
    """
    if mask is not None:
        router_logits = router_logits + mask
    n = router_logits.shape[-1]
    work = router_logits
    idxs, vals = [], []
    for _ in range(k):
        i = jnp.argmax(work, axis=-1)  # [T]
        v = jnp.max(work, axis=-1)
        idxs.append(i)
        vals.append(v)
        work = jnp.where(jax.nn.one_hot(i, n, dtype=bool), -jnp.inf, work)
    idx = jnp.stack(idxs, axis=-1)
    val = jnp.stack(vals, axis=-1)
    probs = jax.nn.softmax(val, axis=-1)
    return idx, probs


def dense_gates(idx, probs, n, dtype=jnp.float32):
    """Scatter top-k (idx, probs) back to a dense [T, n] gate matrix."""
    return jnp.sum(jax.nn.one_hot(idx, n, dtype=dtype) * probs[..., None], axis=1)


def moe_layer_dense(x, w_router, w_gate, w_up, w_down, k, mask=None):
    """Eq. (1) computed densely: y = sum_i P_i(x) E_i(x)."""
    logits = x @ w_router  # [T, n]
    idx, probs = route_topk(logits, k, mask)
    gates = dense_gates(idx, probs, w_gate.shape[0], x.dtype)  # [T, n]
    outs = expert_ffn_dense(x, w_gate, w_up, w_down)  # [T, n, d]
    return jnp.einsum("tn,tnd->td", gates, outs)
