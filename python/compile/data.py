"""Synthetic-language substrate.

The paper calibrates on C4/MATH/CodeQA and evaluates 8 LM-Harness zero-shot
tasks plus MedMCQA.  None of those are available (or meaningful) for the tiny
models we can train in this sandbox, so this module builds the closest
synthetic equivalent that exercises the same code paths (see DESIGN.md
"Substitutions"):

* a vocabulary with structural token classes (subjects, relations, objects,
  digits, operators, brackets, filler words),
* four corpus domains — ``general`` (Zipfian bigram text with embedded KB
  facts), ``math`` (modular arithmetic), ``code`` (bracket/key-value
  patterns), ``med`` (a held-out specialist fact domain),
* a knowledge base of (subject, relation, object) facts split into frequent
  ("easy"), rare ("challenge") and two-hop composable subsets,
* nine zero-shot multiple-choice benchmarks mirroring the paper's suite,
* binary serialisation shared with the Rust loaders (``rust/src/data``).

Everything is deterministic given the seed; Python writes the datasets once
at artifact-build time and Rust only ever reads them.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field

import numpy as np

# ---------------------------------------------------------------------------
# Vocabulary layout (single source of truth; mirrored in rust/src/data/mod.rs)
# ---------------------------------------------------------------------------

VOCAB_SIZE = 448

PAD = 0
BOS = 1
EOS = 2
SEP = 3  # question/answer separator, also used as "=>"
Q = 4    # "question:" marker
A = 5    # "answer:" marker
TRUE_TOK = 6
FALSE_TOK = 7
YES_TOK = 8
NO_TOK = 9

# token-class ranges [start, end)  — sized for the 1-core training budget
SUBJ = (16, 48)      # 32 subjects
REL = (48, 56)       # 8 relations
OBJ = (56, 88)       # 32 objects
DIGIT = (88, 105)    # 17 "digits" 0..16 (mod-17 arithmetic)
OP_ADD, OP_MUL, OP_EQ = 105, 106, 107
LBRACK, RBRACK, LPAREN, RPAREN = 108, 109, 110, 111
KEY = (112, 128)     # 16 code keys
VAL = (128, 144)     # 16 code values
COLON = 144
MED_SUBJ = (145, 161)  # 16 specialist subjects (held-out domain)
MED_OBJ = (161, 177)   # 16 specialist objects
FILLER = (192, 448)    # 256 filler words for general text

MOD = 17  # modulus for the arithmetic domain


def _rng(seed: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(seed))


# ---------------------------------------------------------------------------
# Knowledge base
# ---------------------------------------------------------------------------


@dataclass
class KnowledgeBase:
    """(subject, relation) -> object facts with frequency tiers.

    ``easy`` facts appear often in the general corpus (ARC-e analog), ``hard``
    facts appear rarely (ARC-c analog), ``med`` facts live in their own
    domain corpus only (MedMCQA analog).  ``hops`` are (s, r1, r2) -> object
    chains for the two-hop OBQA analog: s --r1--> m(treated as subject) --r2--> o.
    """

    easy: dict = field(default_factory=dict)
    hard: dict = field(default_factory=dict)
    med: dict = field(default_factory=dict)

    @staticmethod
    def build(seed: int = 1234) -> "KnowledgeBase":
        rng = _rng(seed)
        kb = KnowledgeBase()
        n_subj = SUBJ[1] - SUBJ[0]
        n_rel = REL[1] - REL[0]
        # every (subject, relation) pair gets a deterministic object; the
        # first 60% of subjects form the "easy" tier, the rest "hard".
        for s in range(SUBJ[0], SUBJ[1]):
            for r in range(REL[0], REL[1]):
                o = int(rng.integers(OBJ[0], OBJ[1]))
                tier = kb.easy if (s - SUBJ[0]) < int(0.6 * n_subj) else kb.hard
                tier[(s, r)] = o
        for s in range(MED_SUBJ[0], MED_SUBJ[1]):
            for r in range(REL[0], REL[0] + 4):  # med uses 4 relations
                kb.med[(s, r)] = int(rng.integers(MED_OBJ[0], MED_OBJ[1]))
        _ = n_rel
        return kb

    def all_facts(self) -> dict:
        d = dict(self.easy)
        d.update(self.hard)
        return d

    def hop(self, s: int, r1: int, r2: int):
        """Two-hop chain: object of (s, r1) maps into the subject range via a
        fixed modular fold, then (s', r2) gives the final object."""
        facts = self.all_facts()
        o1 = facts.get((s, r1))
        if o1 is None:
            return None
        s2 = SUBJ[0] + (o1 - OBJ[0]) % (SUBJ[1] - SUBJ[0])
        return facts.get((s2, r2))


# ---------------------------------------------------------------------------
# Corpus generators (domains)
# ---------------------------------------------------------------------------


class CorpusGen:
    """Token-stream generators for the four calibration/training domains."""

    def __init__(self, kb: KnowledgeBase, seed: int = 7):
        self.kb = kb
        self.seed = seed
        rng = _rng(seed)
        # Zipfian unigram over filler words + a sparse bigram transition
        n_fill = FILLER[1] - FILLER[0]
        ranks = np.arange(1, n_fill + 1)
        self.fill_p = (1.0 / ranks) / np.sum(1.0 / ranks)
        # each filler word prefers a small successor set -> learnable bigrams
        self.succ = rng.integers(0, n_fill, size=(n_fill, 4))

    # -- general: filler text with embedded facts ---------------------------
    def general(self, rng: np.random.Generator, length: int) -> list:
        toks: list = []
        n_fill = FILLER[1] - FILLER[0]
        cur = int(rng.choice(n_fill, p=self.fill_p))
        easy_keys = list(self.kb.easy.keys())
        hard_keys = list(self.kb.hard.keys())
        while len(toks) < length:
            u = rng.random()
            if u < 0.12:  # frequent (easy) fact sentence
                s, r = easy_keys[int(rng.integers(len(easy_keys)))]
                toks += [s, r, SEP, self.kb.easy[(s, r)], EOS]
            elif u < 0.17:  # rare (hard) fact sentence
                s, r = hard_keys[int(rng.integers(len(hard_keys)))]
                toks += [s, r, SEP, self.kb.hard[(s, r)], EOS]
            elif u < 0.21:  # short arithmetic interjection
                toks += self._math_stmt(rng)
            else:  # bigram filler text
                step = int(rng.integers(4))
                cur = int(self.succ[cur, step])
                toks.append(FILLER[0] + cur)
        return toks[:length]

    # -- math: a + b = c (mod 17), a * b = c ---------------------------------
    def _math_stmt(self, rng: np.random.Generator) -> list:
        a = int(rng.integers(MOD))
        b = int(rng.integers(MOD))
        if rng.random() < 0.5:
            c, op = (a + b) % MOD, OP_ADD
        else:
            c, op = (a * b) % MOD, OP_MUL
        return [DIGIT[0] + a, op, DIGIT[0] + b, OP_EQ, DIGIT[0] + c, EOS]

    def math(self, rng: np.random.Generator, length: int) -> list:
        toks: list = []
        while len(toks) < length:
            toks += self._math_stmt(rng)
        return toks[:length]

    # -- code: nested brackets + key:value bindings that are later re-read ---
    def code(self, rng: np.random.Generator, length: int) -> list:
        toks: list = []
        while len(toks) < length:
            bindings = {}
            toks.append(LBRACK)
            for _ in range(int(rng.integers(2, 6))):
                k = int(rng.integers(KEY[0], KEY[1]))
                v = int(rng.integers(VAL[0], VAL[1]))
                bindings[k] = v
                toks += [k, COLON, v]
            toks.append(RBRACK)
            # re-read: "( key => value )" forces the model to bind/recall
            if bindings:
                k = list(bindings.keys())[int(rng.integers(len(bindings)))]
                toks += [LPAREN, k, SEP, bindings[k], RPAREN, EOS]
        return toks[:length]

    # -- med: specialist fact domain (held out of general corpus) -----------
    def med(self, rng: np.random.Generator, length: int) -> list:
        toks: list = []
        keys = list(self.kb.med.keys())
        while len(toks) < length:
            s, r = keys[int(rng.integers(len(keys)))]
            toks += [s, r, SEP, self.kb.med[(s, r)], EOS]
        return toks[:length]

    DOMAINS = ("general", "math", "code", "med")

    def stream(self, domain: str, seed: int, length: int) -> np.ndarray:
        rng = _rng(seed)
        fn = getattr(self, domain)
        return np.asarray(fn(rng, length), dtype=np.int32)

    def training_mix(self, seed: int, n_tokens: int) -> np.ndarray:
        """Training corpus: 70% general / 12% math / 12% code / 6% med."""
        rng = _rng(seed)
        chunks = []
        remaining = n_tokens
        props = [("general", 0.70), ("math", 0.12), ("code", 0.12), ("med", 0.06)]
        for i, (dom, p) in enumerate(props):
            ln = int(n_tokens * p) if i < len(props) - 1 else remaining
            ln = min(ln, remaining)
            chunks.append(self.stream(dom, int(rng.integers(1 << 30)), ln))
            remaining -= ln
        toks = np.concatenate(chunks)
        # shuffle at sentence granularity by permuting fixed-size blocks
        block = 64
        n_blk = len(toks) // block
        perm = rng.permutation(n_blk)
        return toks[: n_blk * block].reshape(n_blk, block)[perm].reshape(-1)


# ---------------------------------------------------------------------------
# Benchmarks
# ---------------------------------------------------------------------------


@dataclass
class MCItem:
    """One multiple-choice item: prompt tokens + per-choice completion tokens."""

    prompt: list
    choices: list  # list[list[int]]
    answer: int


def _distractors(rng, correct: int, lo: int, hi: int, k: int) -> list:
    out = []
    while len(out) < k:
        c = int(rng.integers(lo, hi))
        if c != correct and c not in out:
            out.append(c)
    return out


class BenchmarkGen:
    """The nine zero-shot benchmarks (see DESIGN.md table)."""

    def __init__(self, kb: KnowledgeBase, corpus: CorpusGen):
        self.kb = kb
        self.corpus = corpus

    def _fact_item(self, rng, facts: dict) -> MCItem:
        keys = list(facts.keys())
        s, r = keys[int(rng.integers(len(keys)))]
        o = facts[(s, r)]
        cands = [o] + _distractors(rng, o, OBJ[0], OBJ[1], 3)
        order = rng.permutation(4)
        choices = [[cands[i]] for i in order]
        return MCItem([Q, s, r, SEP], choices, int(np.argwhere(order == 0)[0][0]))

    def arc_e(self, rng) -> MCItem:
        return self._fact_item(rng, self.kb.easy)

    def arc_c(self, rng) -> MCItem:
        return self._fact_item(rng, self.kb.hard)

    def boolq(self, rng) -> MCItem:
        facts = self.kb.all_facts()
        keys = list(facts.keys())
        s, r = keys[int(rng.integers(len(keys)))]
        o = facts[(s, r)]
        truthy = rng.random() < 0.5
        shown = o if truthy else _distractors(rng, o, OBJ[0], OBJ[1], 1)[0]
        prompt = [Q, s, r, SEP, shown, A]
        choices = [[YES_TOK], [NO_TOK]]
        return MCItem(prompt, choices, 0 if truthy else 1)

    def hella(self, rng) -> MCItem:
        """Plausible continuation under the bigram grammar."""
        n_fill = FILLER[1] - FILLER[0]
        cur = int(rng.integers(n_fill))
        prompt = [BOS]
        for _ in range(6):
            cur = int(self.corpus.succ[cur, int(rng.integers(4))])
            prompt.append(FILLER[0] + cur)
        good = [FILLER[0] + int(self.corpus.succ[cur, int(rng.integers(4))])]
        succ_set = set(int(x) for x in self.corpus.succ[cur])
        bads = []
        while len(bads) < 3:
            w = int(rng.integers(n_fill))
            cand = [FILLER[0] + w]
            if w not in succ_set and cand != good and cand not in bads:
                bads.append(cand)
        cands = [good] + bads
        order = rng.permutation(4)
        choices = [cands[i] for i in order]
        return MCItem(prompt, choices, int(np.argwhere(order == 0)[0][0]))

    def mmlu(self, rng) -> MCItem:
        a = int(rng.integers(MOD))
        b = int(rng.integers(MOD))
        if rng.random() < 0.5:
            c, op = (a + b) % MOD, OP_ADD
        else:
            c, op = (a * b) % MOD, OP_MUL
        cands = [c] + [x % MOD for x in _distractors(rng, c, 0, MOD, 3)]
        order = rng.permutation(4)
        choices = [[DIGIT[0] + cands[i]] for i in order]
        prompt = [Q, DIGIT[0] + a, op, DIGIT[0] + b, OP_EQ]
        return MCItem(prompt, choices, int(np.argwhere(order == 0)[0][0]))

    def obqa(self, rng) -> MCItem:
        facts = self.kb.all_facts()
        while True:
            s = int(rng.integers(SUBJ[0], SUBJ[1]))
            r1 = int(rng.integers(REL[0], REL[1]))
            r2 = int(rng.integers(REL[0], REL[1]))
            o = self.kb.hop(s, r1, r2)
            if o is not None:
                break
        cands = [o] + _distractors(rng, o, OBJ[0], OBJ[1], 3)
        order = rng.permutation(4)
        choices = [[cands[i]] for i in order]
        return MCItem([Q, s, r1, r2, SEP], choices, int(np.argwhere(order == 0)[0][0]))

    def rte(self, rng) -> MCItem:
        facts = self.kb.all_facts()
        keys = list(facts.keys())
        s, r = keys[int(rng.integers(len(keys)))]
        o = facts[(s, r)]
        entail = rng.random() < 0.5
        o2 = o if entail else _distractors(rng, o, OBJ[0], OBJ[1], 1)[0]
        # premise: s r => o ; hypothesis: s r => o2 ; entailed?
        prompt = [s, r, SEP, o, EOS, s, r, SEP, o2, A]
        choices = [[TRUE_TOK], [FALSE_TOK]]
        return MCItem(prompt, choices, 0 if entail else 1)

    def wino(self, rng) -> MCItem:
        """Binding/recall: code-style key binding then query (coref analog)."""
        k1 = int(rng.integers(KEY[0], KEY[1]))
        k2 = int(rng.integers(KEY[0], KEY[1]))
        while k2 == k1:
            k2 = int(rng.integers(KEY[0], KEY[1]))
        v1 = int(rng.integers(VAL[0], VAL[1]))
        v2 = int(rng.integers(VAL[0], VAL[1]))
        while v2 == v1:
            v2 = int(rng.integers(VAL[0], VAL[1]))
        which = rng.random() < 0.5
        qk = k1 if which else k2
        good, bad = (v1, v2) if which else (v2, v1)
        prompt = [LBRACK, k1, COLON, v1, k2, COLON, v2, RBRACK, LPAREN, qk, SEP]
        first = rng.random() < 0.5
        choices = [[good], [bad]] if first else [[bad], [good]]
        return MCItem(prompt, choices, 0 if first else 1)

    def med(self, rng) -> MCItem:
        return self._fact_item(rng, self.kb.med)

    TASKS = (
        "arc_e",
        "arc_c",
        "boolq",
        "hella",
        "mmlu",
        "obqa",
        "rte",
        "wino",
        "med",
    )

    def dataset(self, task: str, n_items: int, seed: int) -> list:
        rng = _rng(seed)
        fn = getattr(self, task)
        return [fn(rng) for _ in range(n_items)]


# ---------------------------------------------------------------------------
# Binary serialisation (shared with rust/src/data)
# ---------------------------------------------------------------------------
#
# Benchmark file ("HCEV"):
#   magic u32 'HCEV' | version u32 | n_items u32 | n_choices u32
#   then per item: prompt_len u32, prompt i32*, answer u32,
#                  per choice: len u32, toks i32*
# Token-stream file ("HCTS"): magic | version | n u32 | toks i32*


def write_benchmark(path: str, items: list) -> None:
    n_choices = len(items[0].choices)
    with open(path, "wb") as f:
        f.write(struct.pack("<4sIII", b"HCEV", 1, len(items), n_choices))
        for it in items:
            assert len(it.choices) == n_choices
            f.write(struct.pack("<I", len(it.prompt)))
            f.write(np.asarray(it.prompt, dtype=np.int32).tobytes())
            f.write(struct.pack("<I", it.answer))
            for ch in it.choices:
                f.write(struct.pack("<I", len(ch)))
                f.write(np.asarray(ch, dtype=np.int32).tobytes())


def read_benchmark(path: str) -> list:
    with open(path, "rb") as f:
        magic, ver, n_items, n_choices = struct.unpack("<4sIII", f.read(16))
        assert magic == b"HCEV" and ver == 1
        items = []
        for _ in range(n_items):
            (plen,) = struct.unpack("<I", f.read(4))
            prompt = np.frombuffer(f.read(4 * plen), dtype=np.int32).tolist()
            (ans,) = struct.unpack("<I", f.read(4))
            choices = []
            for _ in range(n_choices):
                (clen,) = struct.unpack("<I", f.read(4))
                choices.append(np.frombuffer(f.read(4 * clen), dtype=np.int32).tolist())
            items.append(MCItem(prompt, choices, ans))
        return items


def write_tokens(path: str, toks: np.ndarray) -> None:
    toks = np.asarray(toks, dtype=np.int32)
    with open(path, "wb") as f:
        f.write(struct.pack("<4sII", b"HCTS", 1, len(toks)))
        f.write(toks.tobytes())


def read_tokens(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        magic, ver, n = struct.unpack("<4sII", f.read(12))
        assert magic == b"HCTS" and ver == 1
        return np.frombuffer(f.read(4 * n), dtype=np.int32)
