"""Build-time training of the simulated SMoE models.

The paper is retraining-*free*: it starts from converged pretrained SMoE
checkpoints.  We cannot download Qwen/Mixtral here, so `make artifacts`
trains each simulated model once on the synthetic corpus (DESIGN.md
"Substitutions") — a few hundred Adam steps is enough for the tiny models to
learn the benchmark skills and for experts to specialise, which is the
property the merging experiments need.  Nothing here ever runs again after
artifacts are built.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M


def lr_schedule(step: int, total: int, peak: float = 2.5e-3, warmup: int = 60):
    if step < warmup:
        return peak * (step + 1) / warmup
    frac = (step - warmup) / max(1, total - warmup)
    return peak * (0.05 + 0.95 * 0.5 * (1 + np.cos(np.pi * frac)))


def build_training_tokens(seed: int, n_tokens: int) -> np.ndarray:
    """Training mix: corpus domains + task-format (QA) exposure.

    43% general / 12% math / 10% code / 5% med / 30% QA-format samples.
    The QA component plays the role instruction-ish pretraining data plays
    for the paper's LLMs: without format exposure, zero-shot option scoring
    of unseen markers is chance for a tiny model.
    """
    kb = D.KnowledgeBase.build()
    corpus = D.CorpusGen(kb)
    bench = D.BenchmarkGen(kb, corpus)
    rng = np.random.Generator(np.random.Philox(seed))

    chunks = []
    for dom, frac in (("general", 0.43), ("math", 0.12), ("code", 0.10), ("med", 0.05)):
        chunks.append(corpus.stream(dom, int(rng.integers(1 << 30)), int(n_tokens * frac)))
    qa_toks: list = []
    target = int(n_tokens * 0.30)
    while len(qa_toks) < target:
        for task in D.BenchmarkGen.TASKS:
            item = getattr(bench, task)(rng)
            qa_toks += item.prompt + item.choices[item.answer] + [D.EOS]
    chunks.append(np.asarray(qa_toks[:target], dtype=np.int32))
    toks = np.concatenate(chunks)
    block = 64
    n_blk = len(toks) // block
    perm = rng.permutation(n_blk)
    return toks[: n_blk * block].reshape(n_blk, block)[perm].reshape(-1)


def train(cfg: M.ModelCfg, *, steps: int = 1000, batch: int = 8, seq: int = 64,
          seed: int = 0, log_every: int = 50, tokens: np.ndarray | None = None,
          verbose: bool = True) -> dict:
    """Train one model; returns the trained parameter dict (and logs loss)."""
    if tokens is None:
        tokens = build_training_tokens(seed=seed + 11, n_tokens=max(400_000, steps * batch * seq // 2))
    n_seq = len(tokens) // seq
    seqs = tokens[: n_seq * seq].reshape(n_seq, seq)

    params = M.init_params(cfg, seed=seed)
    opt = M.adam_init(params)
    step_fn = M.make_train_step(cfg)
    rng = np.random.Generator(np.random.Philox(seed + 99))

    t0 = time.time()
    history = []
    for it in range(steps):
        idx = rng.integers(0, n_seq, size=batch)
        ids = jnp.asarray(seqs[idx], dtype=jnp.int32)
        lr = lr_schedule(it, steps)
        params, opt, loss, ce = step_fn(params, opt, ids, lr)
        if it % log_every == 0 or it == steps - 1:
            ce_v = float(ce)
            history.append((it, ce_v))
            if verbose:
                print(
                    f"[{cfg.name}] step {it:4d}  ce={ce_v:.4f}  "
                    f"ppl={np.exp(ce_v):.1f}  ({time.time()-t0:.0f}s)",
                    flush=True,
                )
    params = {k: np.asarray(v) for k, v in params.items()}
    return params, history
