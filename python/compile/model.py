"""L2 — the SMoE transformer (JAX), built on the L1 Pallas kernel.

A LLaMA-structured sparse-MoE language model exactly as described in
Section 2.1 of the paper: RMSNorm, causal multi-head attention, and an SMoE
FFN block with a linear top-k router (Eq. 3), SwiGLU experts (Eq. 2) and the
weighted-sum combine (Eq. 1).  Three model families are shipped (DESIGN.md
"Substitutions"): ``qwensim`` (many small experts), ``mixsim`` (few large
experts) and ``dssim`` (routed experts + an always-on shared expert,
DeepSeek-MoE style).

Three forward functions are lowered to HLO text by ``aot.py``:

* ``forward_logits``      — n-expert forward with an additive router-mask
                            input [L, n]; serves *every* compression method
                            (merging = duplicated merged weights, router
                            unchanged, exactly Fig. 3; pruning = -inf mask).
* ``forward_logits_compact`` — true r-expert forward with a router remap
                            table [L, n] (original expert -> merged slot),
                            used for the Table 20 efficiency measurements.
* ``forward_calib``       — the calibration pass: dense per-expert outputs
                            (Eq. 4 statistics), routing frequencies, router
                            logit profiles, and subsampled raw outputs /
                            intermediate activations for O-prune and
                            ZipIt/Fix-Dom.

Weights are HLO *parameters* (not constants) so the Rust coordinator can
merge experts in weight space and re-execute without re-lowering.  The
request-path forwards route tokens through the Pallas grouped-FFN kernel;
the training step uses the pure-jnp dense reference (same math, asserted
allclose in pytest) because interpret-mode Pallas is needlessly slow for the
build-time-only training loop.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.moe_ffn import moe_ffn

NEG_INF = -1e30


@dataclass(frozen=True)
class ModelCfg:
    name: str = "qwensim"
    n_layer: int = 4
    d: int = 96           # hidden size (d_h in the paper)
    m: int = 96           # expert FFN size (d_m)
    n_exp: int = 16       # experts per layer (n)
    k: int = 2            # top-k routing
    heads: int = 4
    vocab: int = 448
    t_max: int = 256
    shared: bool = False  # DeepSeek-style always-on shared expert
    m_shared: int = 192
    cap_factor: float = 1.5   # expert capacity factor for dispatch
    block_c: int = 192        # Pallas token-block size (coarse grid: interpret-mode
                              # per-step overhead dominates on CPU; see §Perf)

    @property
    def head_dim(self) -> int:
        return self.d // self.heads

    def capacity(self, n_tokens: int, n_exp: int | None = None) -> int:
        """Per-expert capacity, rounded up to a multiple of block_c."""
        n = n_exp if n_exp is not None else self.n_exp
        c = int(np.ceil(self.k * n_tokens * self.cap_factor / n))
        return int(np.ceil(c / self.block_c) * self.block_c)

    def to_kv(self) -> str:
        """Manifest serialisation shared with rust/src/config."""
        kv = {
            "name": self.name, "n_layer": self.n_layer, "d": self.d,
            "m": self.m, "n_exp": self.n_exp, "k": self.k,
            "heads": self.heads, "vocab": self.vocab, "t_max": self.t_max,
            "shared": int(self.shared), "m_shared": self.m_shared,
            "cap_factor": self.cap_factor, "block_c": self.block_c,
        }
        return "".join(f"{k} = {v}\n" for k, v in kv.items())


QWENSIM = ModelCfg(name="qwensim", n_exp=16, m=96)
MIXSIM = ModelCfg(name="mixsim", n_exp=8, m=192)
DSSIM = ModelCfg(name="dssim", n_exp=16, m=64, shared=True, m_shared=192)

CONFIGS = {c.name: c for c in (QWENSIM, MIXSIM, DSSIM)}

# Reduction schedules mirroring the paper's ratios (25/50/62.5/75%).
REDUCTIONS = {
    "qwensim": [12, 8, 6, 4],
    "mixsim": [6, 4, 3, 2],
    "dssim": [14, 12, 10, 8],
}


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(cfg: ModelCfg, seed: int = 0) -> dict:
    """Initialise weights. Keys are stable and sorted for the AOT interface."""
    key = jax.random.PRNGKey(seed)
    ks = iter(jax.random.split(key, 16 + 16 * cfg.n_layer))
    p = {}
    s = 0.02
    p["embed"] = jax.random.normal(next(ks), (cfg.vocab, cfg.d)) * s
    p["pos"] = jax.random.normal(next(ks), (cfg.t_max, cfg.d)) * s
    p["ln_f"] = jnp.ones((cfg.d,))
    for l in range(cfg.n_layer):
        pre = f"layer{l:02d}."
        for w in ("wq", "wk", "wv", "wo"):
            p[pre + "attn." + w] = jax.random.normal(next(ks), (cfg.d, cfg.d)) * s
        p[pre + "ln1"] = jnp.ones((cfg.d,))
        p[pre + "ln2"] = jnp.ones((cfg.d,))
        p[pre + "router"] = jax.random.normal(next(ks), (cfg.d, cfg.n_exp)) * s
        p[pre + "exp.wg"] = jax.random.normal(next(ks), (cfg.n_exp, cfg.d, cfg.m)) * s
        p[pre + "exp.wu"] = jax.random.normal(next(ks), (cfg.n_exp, cfg.d, cfg.m)) * s
        p[pre + "exp.wd"] = jax.random.normal(next(ks), (cfg.n_exp, cfg.m, cfg.d)) * s
        if cfg.shared:
            p[pre + "shared.wg"] = jax.random.normal(next(ks), (cfg.d, cfg.m_shared)) * s
            p[pre + "shared.wu"] = jax.random.normal(next(ks), (cfg.d, cfg.m_shared)) * s
            p[pre + "shared.wd"] = jax.random.normal(next(ks), (cfg.m_shared, cfg.d)) * s
    return p


def param_names(cfg: ModelCfg) -> list:
    return sorted(init_params(cfg, 0).keys())


def compact_params(params: dict, r: int) -> dict:
    """Shape skeleton for the r-expert compact variant (weights themselves are
    produced by the Rust merger; this is used for lowering example shapes)."""
    return {k: (v[:r] if ".exp." in k else v) for k, v in params.items()}


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps: float = 1e-6):
    return x * w * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def attention(cfg: ModelCfg, p: dict, pre: str, h):
    """Causal multi-head self-attention. h: [B, T, d]."""
    b, t, d = h.shape
    hd = cfg.head_dim

    def split(x):  # [B, T, d] -> [B, H, T, hd]
        return x.reshape(b, t, cfg.heads, hd).transpose(0, 2, 1, 3)

    q = split(h @ p[pre + "attn.wq"])
    k = split(h @ p[pre + "attn.wk"])
    v = split(h @ p[pre + "attn.wv"])
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(causal, scores, NEG_INF)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ p[pre + "attn.wo"]


def dispatch(x, idx, probs, n: int, cap: int):
    """Capacity-based token dispatch.

    Args:
      x: [T, d] tokens; idx/probs: [T, k] routing decisions.
    Returns:
      x_d [n, cap, d], plus (e_flat, pos_flat, keep) for the combine.
    """
    t, k = idx.shape
    e_flat = idx.reshape(-1)  # [T*k], token-major
    onehot = jax.nn.one_hot(e_flat, n, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # queue position per entry
    p_flat = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]
    keep = p_flat < cap
    x_rep = jnp.repeat(x, k, axis=0)  # [T*k, d]
    x_d = jnp.zeros((n, cap, x.shape[-1]), x.dtype)
    x_d = x_d.at[e_flat, p_flat].set(x_rep, mode="drop")
    return x_d, e_flat, p_flat, keep


def combine(out_d, e_flat, p_flat, keep, probs):
    """Inverse of dispatch: gather expert outputs, weight by gate probs."""
    t, k = probs.shape
    gathered = out_d.at[e_flat, p_flat].get(mode="fill", fill_value=0.0)
    gathered = jnp.where(keep[:, None], gathered, 0.0)  # [T*k, d]
    gathered = gathered.reshape(t, k, -1)
    return jnp.sum(gathered * probs[..., None], axis=1)


def moe_block(cfg: ModelCfg, p: dict, pre: str, x, mask_l, *, use_pallas: bool,
              remap_l=None, n_slots=None):
    """One SMoE FFN block on flattened tokens x: [T, d].

    mask_l: additive router mask [n_exp].
    remap_l: optional [n_exp] i32 slot remap (compact variant).
    n_slots: number of physical expert slots (r for compact, else n_exp).
    """
    slots = n_slots if n_slots is not None else cfg.n_exp
    logits = x @ p[pre + "router"]  # [T, n] — router always keeps n outputs
    idx, probs = ref.route_topk(logits, cfg.k, mask_l)
    t = x.shape[0]
    if remap_l is not None:
        idx = remap_l[idx]
    # capacity-based dispatch: total slot compute is ~k*T*cap_factor
    # regardless of the expert count, so merging keeps latency flat while
    # shrinking weight memory — exactly the paper's Table 20 observation.
    cap = cfg.capacity(t, slots)
    wg, wu, wd = p[pre + "exp.wg"], p[pre + "exp.wu"], p[pre + "exp.wd"]
    x_d, e_flat, p_flat, keep = dispatch(x, idx, probs, slots, cap)
    if use_pallas:
        out_d = moe_ffn(x_d, wg, wu, wd, block_c=cfg.block_c)
    else:
        out_d = ref.moe_ffn_ref(x_d, wg, wu, wd)
    y = combine(out_d, e_flat, p_flat, keep, probs)
    if cfg.shared:
        y = y + ref.swiglu(
            x, p[pre + "shared.wg"], p[pre + "shared.wu"], p[pre + "shared.wd"]
        )
    return y


# ---------------------------------------------------------------------------
# Forwards
# ---------------------------------------------------------------------------


def forward_logits(cfg: ModelCfg, p: dict, ids, router_mask, *,
                   use_pallas: bool = True, remap=None, n_slots=None):
    """ids: [B, T] i32; router_mask: [L, n] additive f32. Returns [B, T, V].

    remap: optional [L, n] i32 for the compact variant.
    """
    b, t = ids.shape
    h = p["embed"][ids] + p["pos"][:t][None, :, :]
    for l in range(cfg.n_layer):
        pre = f"layer{l:02d}."
        h = h + attention(cfg, p, pre, rmsnorm(h, p[pre + "ln1"]))
        hf = rmsnorm(h, p[pre + "ln2"]).reshape(b * t, cfg.d)
        y = moe_block(
            cfg, p, pre, hf, router_mask[l], use_pallas=use_pallas,
            remap_l=None if remap is None else remap[l], n_slots=n_slots,
        )
        h = h + y.reshape(b, t, cfg.d)
    h = rmsnorm(h, p["ln_f"])
    return h @ p["embed"].T


def forward_logits_compact(cfg: ModelCfg, p: dict, ids, router_mask, remap, r: int,
                           *, use_pallas: bool = True):
    """True r-expert forward (expert tensors are [r, d, m])."""
    return forward_logits(
        cfg, p, ids, router_mask, use_pallas=use_pallas, remap=remap, n_slots=r
    )


def forward_calib(cfg: ModelCfg, p: dict, ids, *, t_sub: int = 512,
                  t_act: int = 256):
    """Calibration pass over ids [B, T].

    Dense per-expert compute (every expert on every token) so the statistics
    of Eq. (4) are exact, plus everything the baselines need.

    Returns a tuple (all f32):
      mean_out  [L, n, d]   — o_j = E_x[E_j(x)]            (HC-SMoE metric)
      counts    [L, n]      — top-k routing frequencies     (freq merging, F-prune)
      probs_sum [L, n]      — sum of full-softmax router scores (S-prune)
      gate_sum  [L, n]      — sum of top-k gate weights
      rl_sub    [L, Ts, n]  — router-logit profiles          (M-SMoE metric)
      raw_sub   [L, n, Ts, d] — per-expert outputs on Ts subsampled tokens (O-prune)
      act_sub   [L, n, Ta, m] — intermediate activations     (ZipIt / Fix-Dom)
      hid_sub   [L, Ts, d]  — pre-MoE hidden states on the subsampled tokens
    """
    b, t = ids.shape
    tok = b * t
    assert t_sub <= tok and t_act <= t_sub
    stride = tok // t_sub
    sub_idx = jnp.arange(t_sub) * stride
    act_idx = sub_idx[:t_act]

    h = p["embed"][ids] + p["pos"][:t][None, :, :]
    acc = {k: [] for k in
           ("mean_out", "counts", "probs_sum", "gate_sum", "rl_sub",
            "raw_sub", "act_sub", "hid_sub")}
    for l in range(cfg.n_layer):
        pre = f"layer{l:02d}."
        h = h + attention(cfg, p, pre, rmsnorm(h, p[pre + "ln1"]))
        hf = rmsnorm(h, p[pre + "ln2"]).reshape(tok, cfg.d)
        logits = hf @ p[pre + "router"]  # [tok, n]
        idx, probs = ref.route_topk(logits, cfg.k)
        gates = ref.dense_gates(idx, probs, cfg.n_exp)  # [tok, n]
        outs = ref.expert_ffn_dense(
            hf, p[pre + "exp.wg"], p[pre + "exp.wu"], p[pre + "exp.wd"]
        )  # [tok, n, d]
        acc["mean_out"].append(jnp.mean(outs, axis=0))
        acc["counts"].append(
            jnp.sum(ref.dense_gates(idx, jnp.ones_like(probs), cfg.n_exp), axis=0)
        )
        acc["probs_sum"].append(jnp.sum(jax.nn.softmax(logits, axis=-1), axis=0))
        acc["gate_sum"].append(jnp.sum(gates, axis=0))
        acc["rl_sub"].append(logits[sub_idx])
        acc["raw_sub"].append(outs[sub_idx].transpose(1, 0, 2))
        acts = ref.expert_act_dense(
            hf[act_idx], p[pre + "exp.wg"], p[pre + "exp.wu"]
        )  # [Ta, n, m]
        acc["act_sub"].append(acts.transpose(1, 0, 2))
        acc["hid_sub"].append(hf[sub_idx])
        y = jnp.einsum("tn,tnd->td", gates, outs)
        if cfg.shared:
            y = y + ref.swiglu(
                hf, p[pre + "shared.wg"], p[pre + "shared.wu"], p[pre + "shared.wd"]
            )
        h = h + y.reshape(b, t, cfg.d)
    return tuple(
        jnp.stack(acc[k]) for k in
        ("mean_out", "counts", "probs_sum", "gate_sum", "rl_sub",
         "raw_sub", "act_sub", "hid_sub")
    )


# ---------------------------------------------------------------------------
# Training (build-time only)
# ---------------------------------------------------------------------------


def lm_loss(cfg: ModelCfg, p: dict, ids):
    """Next-token CE + Switch-style load-balance + z-loss.

    Uses the dense reference MoE (no dispatch) — every expert receives
    gradient signal and there is no token dropping during training.
    """
    b, t = ids.shape
    h = p["embed"][ids] + p["pos"][:t][None, :, :]
    aux = 0.0
    zloss = 0.0
    for l in range(cfg.n_layer):
        pre = f"layer{l:02d}."
        h = h + attention(cfg, p, pre, rmsnorm(h, p[pre + "ln1"]))
        hf = rmsnorm(h, p[pre + "ln2"]).reshape(b * t, cfg.d)
        logits = hf @ p[pre + "router"]
        idx, probs = ref.route_topk(logits, cfg.k)
        gates = ref.dense_gates(idx, probs, cfg.n_exp)
        outs = ref.expert_ffn_dense(
            hf, p[pre + "exp.wg"], p[pre + "exp.wu"], p[pre + "exp.wd"]
        )
        y = jnp.einsum("tn,tnd->td", gates, outs)
        if cfg.shared:
            y = y + ref.swiglu(
                hf, p[pre + "shared.wg"], p[pre + "shared.wu"], p[pre + "shared.wd"]
            )
        h = h + y.reshape(b, t, cfg.d)
        # load balancing: n * sum_i f_i * p_i  (Switch Transformer)
        full_p = jax.nn.softmax(logits, axis=-1)
        f = jnp.mean(
            ref.dense_gates(idx, jnp.ones_like(probs), cfg.n_exp), axis=0
        ) / cfg.k
        pbar = jnp.mean(full_p, axis=0)
        aux = aux + cfg.n_exp * jnp.sum(f * pbar)
        zloss = zloss + jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    h = rmsnorm(h, p["ln_f"])
    logits = h @ p["embed"].T
    tgt = ids[:, 1:]
    lsm = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    ce = -jnp.mean(jnp.take_along_axis(lsm, tgt[..., None], axis=-1))
    return ce + 0.02 * aux / cfg.n_layer + 1e-4 * zloss / cfg.n_layer, ce


def adam_init(p: dict):
    return {
        "m": {k: jnp.zeros_like(v) for k, v in p.items()},
        "v": {k: jnp.zeros_like(v) for k, v in p.items()},
        "t": jnp.zeros(()),
    }


def adam_step(p, opt, grads, lr, b1=0.9, b2=0.95, eps=1e-8, wd=1e-4):
    t = opt["t"] + 1.0
    m = {k: b1 * opt["m"][k] + (1 - b1) * grads[k] for k in p}
    v = {k: b2 * opt["v"][k] + (1 - b2) * grads[k] ** 2 for k in p}
    newp = {}
    for k in p:
        mhat = m[k] / (1 - b1 ** t)
        vhat = v[k] / (1 - b2 ** t)
        newp[k] = p[k] - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p[k])
    return newp, {"m": m, "v": v, "t": t}


def make_train_step(cfg: ModelCfg):
    def step(p, opt, ids, lr):
        (loss, ce), grads = jax.value_and_grad(
            lambda q: lm_loss(cfg, q, ids), has_aux=True
        )(p)
        p2, opt2 = adam_step(p, opt, grads, lr)
        return p2, opt2, loss, ce

    return jax.jit(step)
