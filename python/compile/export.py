"""Weights serialisation ("HCWT" format) shared with ``rust/src/weights``.

Layout (little-endian):
    magic   4s  = b"HCWT"
    version u32 = 1
    n       u32 = tensor count
    per tensor (in sorted-name order — the same order the HLO parameters
    were lowered in):
        name_len u32, name utf-8 bytes
        ndim u32, dims u32 * ndim
    data section: f32 raw bytes per tensor, same order, densely packed.
"""

from __future__ import annotations

import struct

import numpy as np


def save_weights(path: str, params: dict) -> None:
    names = sorted(params.keys())
    with open(path, "wb") as f:
        f.write(struct.pack("<4sII", b"HCWT", 1, len(names)))
        for name in names:
            arr = np.asarray(params[name], dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<I", dim))
        for name in names:
            arr = np.ascontiguousarray(np.asarray(params[name], dtype=np.float32))
            f.write(arr.tobytes())


def load_weights(path: str) -> dict:
    with open(path, "rb") as f:
        magic, ver, n = struct.unpack("<4sII", f.read(12))
        assert magic == b"HCWT" and ver == 1
        metas = []
        for _ in range(n):
            (nl,) = struct.unpack("<I", f.read(4))
            name = f.read(nl).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            metas.append((name, dims))
        out = {}
        for name, dims in metas:
            count = int(np.prod(dims)) if dims else 1
            out[name] = np.frombuffer(f.read(4 * count), dtype=np.float32).reshape(dims)
        return out
