"""AOT pipeline: train → export weights → lower HLO text → write datasets.

This is the only entry point that ever runs Python (`make artifacts`); the
Rust coordinator is self-contained afterwards.  Outputs under ``artifacts/``:

    <model>.hcwt                  trained weights (HCWT, rust/src/weights)
    <model>.cfg                   model manifest (key = value)
    <model>.history               training loss curve (step, ce) per line
    hlo/lm_logits_<model>.hlo.txt           n-expert forward + router mask
    hlo/lm_logits_<model>_r<r>.hlo.txt      compact r-expert forward + remap
    hlo/calib_<model>.hlo.txt               calibration-statistics pass
    eval/<task>.bin               benchmark datasets (HCEV)
    calib/<domain>.bin            calibration token streams (HCTS)
    manifest.txt                  global geometry shared with Rust

HLO **text** is the interchange format: jax >= 0.5 serialises protos with
64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import model as M
from . import train as T
from .export import save_weights, load_weights

# Geometry shared with the Rust side (also recorded in manifest.txt).
EVAL_B, EVAL_T = 32, 32          # option-scoring batch
CALIB_B, CALIB_T = 8, 256        # calibration pass: 2048 tokens
T_SUB, T_ACT = 512, 256          # subsampled stats sizes
N_ITEMS = 64                     # items per benchmark task
CALIB_TOKENS = CALIB_B * CALIB_T

TRAIN_STEPS = int(os.environ.get("HCSMOE_TRAIN_STEPS", "1100"))
TRAIN_BATCH = 8
TRAIN_SEQ = 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_lm_logits(cfg: M.ModelCfg, params: dict) -> str:
    names = sorted(params.keys())

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        ids, mask = args[len(names)], args[len(names) + 1]
        return (M.forward_logits(cfg, p, ids, mask, use_pallas=True),)

    specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]
    specs.append(jax.ShapeDtypeStruct((EVAL_B, EVAL_T), jnp.int32))
    specs.append(jax.ShapeDtypeStruct((cfg.n_layer, cfg.n_exp), jnp.float32))
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*specs))


def lower_lm_logits_compact(cfg: M.ModelCfg, params: dict, r: int) -> str:
    cparams = M.compact_params(params, r)
    names = sorted(cparams.keys())

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        ids, mask, remap = args[len(names)], args[len(names) + 1], args[len(names) + 2]
        return (M.forward_logits_compact(cfg, p, ids, mask, remap, r, use_pallas=True),)

    specs = [jax.ShapeDtypeStruct(cparams[n].shape, jnp.float32) for n in names]
    specs.append(jax.ShapeDtypeStruct((EVAL_B, EVAL_T), jnp.int32))
    specs.append(jax.ShapeDtypeStruct((cfg.n_layer, cfg.n_exp), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((cfg.n_layer, cfg.n_exp), jnp.int32))
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*specs))


def lower_calib(cfg: M.ModelCfg, params: dict) -> str:
    names = sorted(params.keys())

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        ids = args[len(names)]
        return M.forward_calib(cfg, p, ids, t_sub=T_SUB, t_act=T_ACT)

    specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]
    specs.append(jax.ShapeDtypeStruct((CALIB_B, CALIB_T), jnp.int32))
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*specs))


def write_datasets(out: str) -> None:
    kb = D.KnowledgeBase.build()
    corpus = D.CorpusGen(kb)
    bench = D.BenchmarkGen(kb, corpus)
    os.makedirs(f"{out}/eval", exist_ok=True)
    os.makedirs(f"{out}/calib", exist_ok=True)
    # benchmark seeds are disjoint from every training/calibration seed
    for i, task in enumerate(D.BenchmarkGen.TASKS):
        items = bench.dataset(task, N_ITEMS, seed=90_000 + i)
        D.write_benchmark(f"{out}/eval/{task}.bin", items)
    # calibration streams: paper uses C4 / MATH / CodeQA (Appendix B.3)
    for dom, seed in (("general", 70_001), ("math", 70_002), ("code", 70_003),
                      ("med", 70_004)):
        D.write_tokens(f"{out}/calib/{dom}.bin", corpus.stream(dom, seed, CALIB_TOKENS))
    # per-benchmark token streams for the frequency analysis (Figs. 6-13)
    for i, task in enumerate(D.BenchmarkGen.TASKS):
        items = bench.dataset(task, N_ITEMS, seed=91_000 + i)
        toks: list = []
        for it in items:
            toks += it.prompt + it.choices[it.answer] + [D.EOS]
        reps = (CALIB_TOKENS + len(toks) - 1) // len(toks)
        stream = (toks * reps)[:CALIB_TOKENS]
        D.write_tokens(f"{out}/calib/task_{task}.bin", np.asarray(stream, np.int32))
    # held-out perplexity stream
    D.write_tokens(f"{out}/calib/ppl_heldout.bin",
                   corpus.stream("general", 70_099, CALIB_TOKENS))


def build_model(name: str, out: str, steps: int, force: bool) -> None:
    cfg = M.CONFIGS[name]
    wpath = f"{out}/{name}.hcwt"
    if os.path.exists(wpath) and not force:
        print(f"[aot] {name}: weights exist, skipping training")
        params = load_weights(wpath)
    else:
        t0 = time.time()
        seed = sum(name.encode()) % 10_000  # stable across interpreter runs
        params, history = T.train(cfg, steps=steps, batch=TRAIN_BATCH, seq=TRAIN_SEQ,
                                  seed=seed)
        print(f"[aot] {name}: trained {steps} steps in {time.time()-t0:.0f}s")
        save_weights(wpath, params)
        with open(f"{out}/{name}.history", "w") as f:
            for step, ce in history:
                f.write(f"{int(step)} {ce:.6f}\n")
    with open(f"{out}/{name}.cfg", "w") as f:
        f.write(cfg.to_kv())

    os.makedirs(f"{out}/hlo", exist_ok=True)
    jobs = [(f"{out}/hlo/lm_logits_{name}.hlo.txt",
             lambda: lower_lm_logits(cfg, params))]
    for r in M.REDUCTIONS[name]:
        jobs.append((f"{out}/hlo/lm_logits_{name}_r{r}.hlo.txt",
                     lambda r=r: lower_lm_logits_compact(cfg, params, r)))
    jobs.append((f"{out}/hlo/calib_{name}.hlo.txt", lambda: lower_calib(cfg, params)))
    for path, fn in jobs:
        if os.path.exists(path) and not force:
            continue
        t0 = time.time()
        text = fn()
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] lowered {os.path.basename(path)} "
              f"({len(text)//1024} KiB, {time.time()-t0:.0f}s)")


def write_manifest(out: str, models: list) -> None:
    with open(f"{out}/manifest.txt", "w") as f:
        f.write(f"eval_b = {EVAL_B}\neval_t = {EVAL_T}\n")
        f.write(f"calib_b = {CALIB_B}\ncalib_t = {CALIB_T}\n")
        f.write(f"t_sub = {T_SUB}\nt_act = {T_ACT}\n")
        f.write(f"n_items = {N_ITEMS}\n")
        f.write(f"models = {','.join(models)}\n")
        f.write(f"tasks = {','.join(D.BenchmarkGen.TASKS)}\n")
        for name in models:
            f.write(f"reductions_{name} = "
                    f"{','.join(str(r) for r in M.REDUCTIONS[name])}\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="qwensim,mixsim,dssim")
    ap.add_argument("--steps", type=int, default=TRAIN_STEPS)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    models = args.models.split(",")
    write_datasets(out)
    print("[aot] datasets written")
    for name in models:
        build_model(name, out, args.steps, args.force)
    write_manifest(out, models)
    print("[aot] done")


if __name__ == "__main__":
    main()
