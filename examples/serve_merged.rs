//! Serving demo: compress qwensim to half its experts with HC-SMoE inside
//! the executor thread, then fire concurrent multiple-choice scoring
//! requests from four client threads through the dynamic batcher and
//! report latency/throughput/batch-fill — the deployment story of
//! Section 1. Runs offline on the native backend (artifacts are
//! synthesized when absent).
//!
//! Run with: `cargo run --release --offline --example serve_merged`

use std::time::{Duration, Instant};

use hc_smoe::bench_support::ensure_artifacts;
use hc_smoe::clustering::Linkage;
use hc_smoe::data::Benchmark;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::pipeline::Method;
use hc_smoe::serving::{serve, BatcherConfig, ServeSpec};
use hc_smoe::similarity::Metric;

fn main() -> anyhow::Result<()> {
    let arts = ensure_artifacts()?;
    let bench = Benchmark::load(arts.root.join("eval/arc_e.bin"))?;
    let n_exp = arts.model_cfg("qwensim")?.n_exp;
    let r = n_exp / 2;
    let spec = ServeSpec {
        artifacts_root: arts.root.to_string_lossy().into_owned(),
        model: "qwensim".into(),
        compress: Some((
            Method::HcSmoe {
                linkage: Linkage::Average,
                metric: Metric::ExpertOutput,
                merge: MergeStrategy::Frequency,
            },
            r,
            "general".into(),
        )),
        kv_budget_bytes: None,
        prefill_chunk: None,
        drafter: None,
    };
    println!("starting executor (compresses {n_exp} -> {r} experts at startup)...");
    let handle = serve(
        spec,
        BatcherConfig { max_rows: 32, max_wait: Duration::from_millis(4) },
    )?;

    let clients = 4usize;
    let per_client = 32usize;
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::new();
    let correct = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| -> anyhow::Result<()> {
        let mut joins = Vec::new();
        for c in 0..clients {
            let tx = handle.sender();
            let bench = &bench;
            let correct = &correct;
            joins.push(s.spawn(move || -> anyhow::Result<Vec<f64>> {
                let mut lats = Vec::new();
                for i in 0..per_client {
                    let item = &bench.items[(c * per_client + i) % bench.items.len()];
                    let rows = item
                        .choices
                        .iter()
                        .map(|ch| {
                            let mut seq = item.prompt.clone();
                            seq.extend_from_slice(ch);
                            hc_smoe::serving::RowSpec {
                                start: item.prompt.len(),
                                end: seq.len(),
                                seq,
                            }
                        })
                        .collect();
                    let (reply, rx) = std::sync::mpsc::channel();
                    let t = Instant::now();
                    tx.send(
                        hc_smoe::serving::ScoreRequest {
                            rows,
                            reply,
                            enqueued: Instant::now(),
                        }
                        .into(),
                    )?;
                    let scores = rx.recv()?;
                    lats.push(t.elapsed().as_secs_f64());
                    let pred = scores
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    if pred == item.answer {
                        correct.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                }
                Ok(lats)
            }));
        }
        for j in joins {
            latencies.extend(j.join().expect("client thread panicked")?);
        }
        Ok(())
    })?;
    let wall = t0.elapsed().as_secs_f64();
    let snap = handle.metrics.snapshot();
    handle.shutdown()?;

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = latencies.len();
    let total = clients * per_client;
    println!(
        "served {total} requests from {clients} clients in {wall:.2}s \
         ({:.1} req/s)",
        total as f64 / wall
    );
    println!(
        "latency p50 {:.1} ms / p90 {:.1} ms / p99 {:.1} ms",
        latencies[n / 2] * 1e3,
        latencies[n * 9 / 10] * 1e3,
        latencies[(n * 99 / 100).min(n - 1)] * 1e3
    );
    println!(
        "batcher: {} batches, mean fill {:.2}, device busy {:.2}s",
        snap.batches,
        snap.mean_batch_fill(32),
        snap.busy_s
    );
    println!(
        "accuracy on served arc_e items: {:.3}",
        correct.load(std::sync::atomic::Ordering::Relaxed) as f64 / total as f64
    );
    Ok(())
}
