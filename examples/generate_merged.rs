//! Generation demo: compress qwensim to half its experts with HC-SMoE,
//! then emit tokens with the KV-cached decode loop — offline, through
//! three variants (original, merged full layout, merged compact r-expert
//! layout) — and finally serve mixed score + generate traffic through the
//! continuous-batching executor. The served generation is bit-identical
//! to the offline one: both run the same seeded `generate::Session`.
//!
//! Run with: `cargo run --release --offline --example generate_merged`

use std::time::Instant;

use hc_smoe::bench_support::ensure_artifacts;
use hc_smoe::clustering::Linkage;
use hc_smoe::generate::{generate, generate_compact, SamplingParams};
use hc_smoe::merging::MergeStrategy;
use hc_smoe::model::ModelContext;
use hc_smoe::pipeline::{Method, Pipeline};
use hc_smoe::serving::{serve, BatcherConfig, ServeSpec};
use hc_smoe::similarity::Metric;

fn fmt(ts: &[i32]) -> String {
    ts.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ")
}

fn main() -> anyhow::Result<()> {
    let arts = ensure_artifacts()?;
    let ctx = ModelContext::load(&arts, "qwensim")?;
    let n_exp = ctx.cfg.n_exp;
    let r = n_exp / 2;
    let method = Method::HcSmoe {
        linkage: Linkage::Average,
        metric: Metric::ExpertOutput,
        merge: MergeStrategy::Frequency,
    };
    println!(
        "qwensim on the {} backend: {} layers x {n_exp} experts, compressing to {r}",
        ctx.backend_name(),
        ctx.cfg.n_layer
    );
    let stats = ctx.calibrate("general")?;
    let plan = Pipeline::new(method.clone()).plan(&ctx, &stats, r)?;
    let cm = plan.apply(&ctx, &stats)?;

    // [BOS, Q, content..., SEP, A] — the benchmark prompt shape
    let prompt: Vec<i32> = vec![1, 4, 20, 50, 33, 3, 5];
    let greedy = SamplingParams::greedy(24, None);
    let sampled = SamplingParams::top_k(8, 0.8, 7, 24, None);

    // 1. offline generation across the three variants
    let original = ctx.load_original()?;
    let merged = cm.load(&ctx)?;
    let (cw, remap) = cm.to_compact(&ctx)?;
    let compact = ctx.load_compact(r, &cw, remap, &cm.label)?;

    println!("\nprompt ({}): {}", prompt.len(), fmt(&prompt));
    let o = generate(&ctx, &original, &prompt, greedy.clone())?;
    println!(
        "original          greedy: {} [{:?}, {:.0} tok/s]",
        fmt(&o.tokens),
        o.finish,
        o.decode_tok_s()
    );
    let m = generate(&ctx, &merged, &prompt, greedy.clone())?;
    println!(
        "merged (full)     greedy: {} [{:?}, {:.0} tok/s]",
        fmt(&m.tokens),
        m.finish,
        m.decode_tok_s()
    );
    let c = generate_compact(&ctx, &compact, &prompt, greedy)?;
    println!(
        "merged (compact)  greedy: {} [{:?}, {:.0} tok/s]",
        fmt(&c.tokens),
        c.finish,
        c.decode_tok_s()
    );
    let s = generate(&ctx, &merged, &prompt, sampled)?;
    println!(
        "merged (full)   seed=7  : {} [{:?}, {:.0} tok/s]",
        fmt(&s.tokens),
        s.finish,
        s.decode_tok_s()
    );
    println!(
        "kv cache: {} B per token, {} B per sequence at t_max={}",
        ctx.cfg.kv_cache_bytes(1),
        ctx.cfg.kv_cache_bytes(ctx.cfg.t_max),
        ctx.cfg.t_max
    );

    // 2. the continuous-batching server under mixed score + generate load
    println!("\nstarting executor (compresses {n_exp} -> {r} experts at startup)...");
    let handle = serve(
        ServeSpec {
            artifacts_root: arts.root.to_string_lossy().into_owned(),
            model: "qwensim".into(),
            compress: Some((method, r, "general".into())),
            kv_budget_bytes: None,
            prefill_chunk: None,
            drafter: None,
        },
        BatcherConfig {
            max_rows: ctx.manifest.eval_b,
            max_wait: std::time::Duration::from_millis(4),
        },
    )?;
    let bench = hc_smoe::data::Benchmark::load(arts.root.join("eval/arc_e.bin"))?;
    let t0 = Instant::now();
    let mut served: Vec<(usize, hc_smoe::generate::Generated)> = Vec::new();
    std::thread::scope(|scope| -> anyhow::Result<()> {
        // generation clients join/leave the running decode batch...
        let mut joins = Vec::new();
        for g in 0..3usize {
            let handle = &handle;
            let prompt = &prompt;
            joins.push(scope.spawn(move || {
                let params = SamplingParams::top_k(8, 0.8, 7 + g as u64, 8 + 4 * g, None);
                handle.generate(prompt, params).map(|out| (g, out))
            }));
        }
        // ...while scoring clients keep the dynamic batcher busy
        for cl in 0..2usize {
            let handle = &handle;
            let bench = &bench;
            scope.spawn(move || {
                for item in bench.items.iter().skip(cl * 8).take(8) {
                    handle.score_item(&item.prompt, &item.choices).unwrap();
                }
            });
        }
        for j in joins {
            served.push(j.join().expect("generation client panicked")?);
        }
        Ok(())
    })?;
    let wall = t0.elapsed().as_secs_f64();
    served.sort_by_key(|(g, _)| *g);
    for (g, out) in &served {
        println!("served gen #{g} (seed {}): {} [{:?}]", 7 + g, fmt(&out.tokens), out.finish);
    }
    // the server runs the same seeded Session loop as the offline API
    let offline = generate(&ctx, &merged, &prompt, SamplingParams::top_k(8, 0.8, 7, 8, None))?;
    assert_eq!(
        served[0].1.tokens, offline.tokens,
        "served generation must replay the offline one bit for bit"
    );
    println!("served gen #0 == offline generate() with the same seed ✓");

    let snap = handle.metrics.snapshot();
    handle.shutdown()?;
    println!(
        "mixed load done in {wall:.2}s: {} score rows in {} batches ({:.1} rows/s busy); \
         {} generations, {} prompt tok prefilled, {} tok decoded \
         ({:.0} tok/s, {:.2} ms/token)",
        snap.rows,
        snap.batches,
        snap.rows_per_sec(),
        snap.gen_requests,
        snap.prefill_tokens,
        snap.gen_tokens,
        snap.decode_tok_s(),
        snap.ms_per_token(),
    );
    Ok(())
}
