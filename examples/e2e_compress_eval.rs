//! End-to-end driver (the DESIGN.md "end-to-end validation" workload):
//! for each simulated model, run the full HC-SMoE pipeline against every
//! baseline at the first two reduction points of the manifest, score the
//! full zero-shot suite through the selected execution backend (native
//! CPU by default — no PJRT or Python required), verify the expected
//! ordering (HC-SMoE >= the best baseline), and report perplexity +
//! output fidelity on held-out text.
//!
//! With real trained artifacts this is the binary whose output is
//! recorded in EXPERIMENTS.md; on a synthesized artifact set (the offline
//! default, also CI's `backend-e2e` smoke) it proves the whole
//! compress → eval → serve loop executes, with near-chance scores.

use hc_smoe::bench_support::{paper_methods, push_row, task_table, Lab, PAPER_TASKS};
use hc_smoe::data::TokenStream;
use hc_smoe::eval::Evaluator;
use hc_smoe::quality::output_fidelity;
use hc_smoe::util::Timer;

fn main() -> anyhow::Result<()> {
    let total = Timer::start();
    for model in ["qwensim", "mixsim"] {
        let lab = Lab::new(model)?;
        println!("== {model}: executing on the {} backend ==", lab.ctx.backend_name());
        let rs = lab.ctx.manifest.reductions[model].clone();
        let mut table = task_table(
            &format!("E2E — {model}: all methods, 25% and 50% reduction"),
            &PAPER_TASKS,
        );
        let ev = Evaluator::new(&lab.ctx)?;
        let original = lab.ctx.load_original()?;
        let stream = TokenStream::load(lab.ctx.arts.calib_tokens_path("ppl_heldout"))?;
        let base_ppl = ev.perplexity(&original, &stream)?;
        let (scores, avg) = lab.eval_original(&PAPER_TASKS)?;
        push_row(&mut table, "None", lab.ctx.cfg.n_exp, &scores, avg);
        println!("{model}: original avg={avg:.4}, held-out ppl={base_ppl:.2}");

        for &r in &rs[..2] {
            let mut best_baseline = f64::MIN;
            let mut hc_avg = f64::MIN;
            for method in paper_methods(lab.ctx.cfg.n_exp, r) {
                let label = method.label();
                let is_hc = label.starts_with("HC-SMoE");
                let (scores, avg) = lab.eval_method(method, r, "general", &PAPER_TASKS)?;
                push_row(&mut table, &label, r, &scores, avg);
                if is_hc {
                    hc_avg = hc_avg.max(avg);
                } else {
                    best_baseline = best_baseline.max(avg);
                }
            }
            let verdict = if hc_avg >= best_baseline { "OK" } else { "VIOLATED" };
            println!(
                "{model} r={r}: HC-SMoE {hc_avg:.4} vs best baseline {best_baseline:.4} \
                 -> paper ordering {verdict}"
            );
        }

        // fidelity of the 50% HC-SMoE model on held-out text
        let method = paper_methods(lab.ctx.cfg.n_exp, rs[1]).pop().unwrap();
        let cm = lab.compress(method, rs[1], "general")?;
        let loaded = cm.load(&lab.ctx)?;
        let ppl = ev.perplexity(&loaded, &stream)?;
        let (l2, cos) = output_fidelity(&lab.ctx, &original, &loaded, &stream, 2)?;
        println!(
            "{model} 50% merged: ppl {base_ppl:.2} -> {ppl:.2}, \
             logit L2 {l2:.1}, cosine {cos:.4}"
        );
        table.print();
        table.append_to("bench_results.md")?;
    }
    println!("e2e driver finished in {:.1}s", total.secs());
    Ok(())
}
