//! Fourth example: an ablation playground showing the library's composable
//! API — mix any clustering (HC/K-means/FCM/single-shot), any similarity
//! metric, and any merging strategy on any model, then inspect cluster
//! structure and accuracy. Mirrors the exploration workflow of Section 4.3.
//!
//! Run with: `cargo run --release --offline --example ablation_playground`

use hc_smoe::bench_support::{Lab, ABLATION_TASKS};
use hc_smoe::clustering::{hierarchical, Linkage};
use hc_smoe::merging::MergeStrategy;
use hc_smoe::pipeline::{Method, PlanKind};
use hc_smoe::quality::silhouette;
use hc_smoe::similarity::{distance_matrix, features, Distance, Metric};

fn main() -> anyhow::Result<()> {
    let lab = Lab::new("mixsim")?;
    let stats = lab.stats("general")?;
    let r = 4;

    // 1. inspect the dendrogram-level structure on layer 0
    println!("== cluster structure (mixsim layer 0, r={r}) ==");
    for metric in [Metric::ExpertOutput, Metric::RouterLogits, Metric::Weight] {
        let feats = features(metric, &lab.ctx.base, &stats.layers[0], 0)?;
        let dist = distance_matrix(&feats, Distance::Euclidean);
        let c = hierarchical(&dist, r, Linkage::Average);
        let sil = silhouette(&feats, &c.assign, r, Distance::Euclidean);
        println!("{:<7} groups={:?} silhouette={sil:.3}", metric.short(), c.groups());
    }

    // 2. cross-product sweep: linkage x merge on the expert-output metric
    println!("\n== linkage x merge sweep (4-task avg accuracy) ==");
    for linkage in [Linkage::Single, Linkage::Complete, Linkage::Average] {
        for merge in [MergeStrategy::Average, MergeStrategy::Frequency] {
            let method = Method::HcSmoe { linkage, metric: Metric::ExpertOutput, merge };
            let (_, avg) = lab.eval_method(method, r, "general", &ABLATION_TASKS)?;
            println!("{:<8} + {:<9} -> {avg:.4}", linkage.short(), merge.short());
        }
    }

    // 3. what got merged with what: name the surviving expert groups
    let method = Method::HcSmoe {
        linkage: Linkage::Average,
        metric: Metric::ExpertOutput,
        merge: MergeStrategy::Frequency,
    };
    let cm = lab.compress(method, r, "general")?;
    if let PlanKind::Merge { groups, .. } = &cm.plan.kind {
        println!("\n== final merge plan ==");
        for (l, g) in groups.iter().enumerate() {
            println!("layer {l}: {g:?}");
        }
    }
    Ok(())
}
