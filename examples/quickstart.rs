//! Quickstart: the five-line HC-SMoE story.
//!
//! Load a simulated SMoE model, collect calibration statistics on the
//! C4-analog corpus, merge the experts of every layer down to half with
//! hierarchical clustering over expert outputs (Algorithm 1), and compare
//! zero-shot accuracy before/after on two benchmarks.
//!
//! Runs offline out of the box: artifacts are discovered, or synthesized
//! in-process when absent, and the model executes on the native CPU
//! backend (`HCSMOE_BACKEND=pjrt` switches to the PJRT path).
//!
//! Run with: `cargo run --release --offline --example quickstart`

use hc_smoe::bench_support::ensure_artifacts;
use hc_smoe::clustering::Linkage;
use hc_smoe::eval::Evaluator;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::model::ModelContext;
use hc_smoe::pipeline::{Method, Pipeline};
use hc_smoe::similarity::Metric;

fn main() -> anyhow::Result<()> {
    let arts = ensure_artifacts()?;
    let ctx = ModelContext::load(&arts, "qwensim")?;
    println!(
        "loaded {} ({} layers x {} experts, top-{}) on the {} backend",
        ctx.cfg.name,
        ctx.cfg.n_layer,
        ctx.cfg.n_exp,
        ctx.cfg.k,
        ctx.backend_name()
    );

    // 1. calibration statistics (Eq. 4: averaged expert outputs)
    let stats = ctx.calibrate("general")?;
    println!("calibrated on {} tokens of the C4-analog corpus", stats.n_tokens);

    // 2. hierarchical clustering + frequency-weighted merging (HC-SMoE)
    let r = ctx.cfg.n_exp / 2;
    let method = Method::HcSmoe {
        linkage: Linkage::Average,
        metric: Metric::ExpertOutput,
        merge: MergeStrategy::Frequency,
    };
    let plan = Pipeline::new(method).plan(&ctx, &stats, r)?;
    let merged = plan.apply(&ctx, &stats)?;
    println!("merged {} -> {r} experts/layer ({})", ctx.cfg.n_exp, merged.label);

    // 3. evaluate before/after
    let ev = Evaluator::new(&ctx)?;
    let original = ctx.load_original()?;
    let compressed = merged.load(&ctx)?;
    for task in ["arc_e", "hella"] {
        let before = ev.accuracy(&original, task)?;
        let after = ev.accuracy(&compressed, task)?;
        println!("{task:8} {before:.4} -> {after:.4}");
    }
    Ok(())
}
