#!/usr/bin/env bash
# Assert the quant_gemm_sweep contract on a full-run BENCH_backend.json:
# the section must exist, and on every (decode, prefill) expert-projection
# row the cache-blocked tiled kernel must at least match the scalar
# reference (tiled_speedup >= 1.0 — a noise-tolerant floor; the register
# tiling is expected well above 1 on any autovectorizing build) and the
# int8 folded-scale kernel must at least match the tiled f32 one
# (int8_ms <= tiled_ms — it streams 4x fewer weight bytes). CI runs this
# in the backend-e2e job after `HCSMOE_BENCH_ONLY=backend cargo bench
# --bench perf_microbench`; contributors can run it locally the same way.
#
# With no argument the script probes both candidate locations: cargo runs
# bench binaries with the PACKAGE root (rust/) as working directory, so
# that is where the JSON lands when invoked via `cargo bench` from the
# workspace root.
#
# The parse relies on bench_support::write_backend_json's stable
# formatting: one JSON object per line, "tiled_speedup" keys only in the
# quant_gemm_sweep section.
set -euo pipefail

cd "$(dirname "$0")/.."

echo "==> kernel parity + property suite (tiled==reference, thread bit-identity, int8 bounds, HCWT v2)"
cargo test --release --test kernels -q

f="${1:-}"
if [ -z "$f" ]; then
  for cand in rust/BENCH_backend.json BENCH_backend.json; do
    [ -f "$cand" ] && { f="$cand"; break; }
  done
fi
[ -n "$f" ] && [ -f "$f" ] || { echo "check_kernels: BENCH_backend.json not found (looked in rust/ and .)"; exit 1; }

grep -q '"quant_gemm_sweep"' "$f" \
  || { echo "check_kernels: $f has no quant_gemm_sweep section"; exit 1; }

rows=$(grep '"tiled_speedup"' "$f" || true)
[ -n "$rows" ] || { echo "check_kernels: quant_gemm_sweep has no rows"; exit 1; }

status=0
while IFS= read -r line; do
  path=$(echo "$line" | sed -n 's/.*"path": "\([^"]*\)".*/\1/p')
  tiled_ms=$(echo "$line" | sed -n 's/.*"tiled_ms": \([0-9][0-9.]*\).*/\1/p')
  int8_ms=$(echo "$line" | sed -n 's/.*"int8_ms": \([0-9][0-9.]*\).*/\1/p')
  tiled_speedup=$(echo "$line" | sed -n 's/.*"tiled_speedup": \([0-9][0-9.]*\).*/\1/p')
  [ -n "$path" ] && [ -n "$tiled_ms" ] && [ -n "$int8_ms" ] && [ -n "$tiled_speedup" ] \
    || { echo "check_kernels: malformed row: $line"; exit 1; }
  awk -v s="$tiled_speedup" 'BEGIN { exit (s >= 1.0) ? 0 : 1 }' || {
    echo "check_kernels: $path — tiled kernel is SLOWER than the scalar reference (speedup = ${tiled_speedup}x) in $f"
    status=1
  }
  awk -v i="$int8_ms" -v t="$tiled_ms" 'BEGIN { exit (i <= t) ? 0 : 1 }' || {
    echo "check_kernels: $path — int8 kernel (${int8_ms} ms) is SLOWER than the tiled f32 kernel (${tiled_ms} ms) in $f"
    status=1
  }
  [ "$status" -eq 0 ] && echo "check_kernels: $path OK — tiled ${tiled_speedup}x vs scalar, int8 ${int8_ms} ms <= tiled ${tiled_ms} ms"
done <<< "$rows"

[ "$status" -eq 0 ] || exit "$status"
echo "check_kernels: OK ($f)"
