#!/usr/bin/env bash
# Gate the adaptive-compression / hot-swap contracts in CI (backend-e2e
# job):
#
#  1. `cargo test --test adapt` — the background rebuild lands exactly the
#     offline-predicted variant fingerprint and post-swap requests emit
#     its offline token stream bit for bit; a stream pinned across a swap
#     under a preemption storm stays bit-identical to its variant's
#     offline run and the storm leaks zero KV blocks; window_tokens=0 is
#     a startup error.
#  2. BENCH_generate.json must contain the `adapt_sweep` section with the
#     before/during/after phases, a hot swap must have landed by the
#     `after` row (swaps >= 1), and the `during` throughput — served
#     while the recompression worker is busy — must hold at least
#     DURING_TOK_S_MIN_FRACTION of the `before` throughput: the rebuild
#     runs off the executor thread and may never stall serving.
#
# With no argument the JSON is probed in rust/ then . (cargo runs bench
# binaries with the package root as working directory).
set -euo pipefail

# serving may slow down while a rebuild shares the host, but must keep at
# least this fraction of its pre-rebuild throughput
DURING_TOK_S_MIN_FRACTION=0.30

cd "$(dirname "$0")/.."

echo "==> adaptive serving test suite (hot-swap identity, preemption storm, knobs)"
cargo test --release --test adapt -q

f="${1:-}"
if [ -z "$f" ]; then
  for cand in rust/BENCH_generate.json BENCH_generate.json; do
    [ -f "$cand" ] && { f="$cand"; break; }
  done
fi
[ -n "$f" ] && [ -f "$f" ] || { echo "check_adapt: BENCH_generate.json not found (looked in rust/ and .)"; exit 1; }

grep -q '"adapt_sweep"' "$f" \
  || { echo "check_adapt: $f has no adapt_sweep section"; exit 1; }

for phase in before during after; do
  grep -q "\"phase\": \"$phase\"" "$f" \
    || { echo "check_adapt: adapt_sweep has no \"$phase\" row"; exit 1; }
done

# a hot swap must have landed by the end of the sweep
swaps=$(grep '"phase": "after"' "$f" | sed -n 's/.*"swaps": \([0-9]*\).*/\1/p')
[ -n "$swaps" ] && [ "$swaps" -ge 1 ] \
  || { echo "check_adapt: no hot swap landed during the sweep (swaps=${swaps:-?})"; exit 1; }

# the background rebuild may never stall serving: during >= fraction of before
before=$(grep '"phase": "before"' "$f" | sed -n 's/.*"tok_s": \([0-9.]*\).*/\1/p')
during=$(grep '"phase": "during"' "$f" | sed -n 's/.*"tok_s": \([0-9.]*\).*/\1/p')
[ -n "$before" ] && [ -n "$during" ] \
  || { echo "check_adapt: adapt_sweep rows missing tok_s fields"; exit 1; }
ok=$(awk -v b="$before" -v d="$during" -v frac="$DURING_TOK_S_MIN_FRACTION" \
  'BEGIN { print (b > 0 && d >= b * frac) ? 1 : 0 }')
[ "$ok" = "1" ] \
  || { echo "check_adapt: serving stalled behind the rebuild — during ${during} tok/s < ${DURING_TOK_S_MIN_FRACTION} x before ${before} tok/s"; exit 1; }

echo "check_adapt: OK — swap landed (swaps=$swaps), during ${during} tok/s vs before ${before} tok/s ($f)"
