#!/usr/bin/env bash
# The exact gate CI runs — contributors run this locally to get the same
# verdict. The first two commands are the repository's tier-1 gate verbatim;
# fmt/clippy extend it for the CI `checks` job.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -- -D warnings"
cargo clippy -- -D warnings

echo "ci_check: all green"
