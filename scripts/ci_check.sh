#!/usr/bin/env bash
# The exact gate CI runs — contributors run this locally to get the same
# verdict. The first two commands are the repository's tier-1 gate verbatim;
# the rest extend it for the CI `checks` job (doc tests, fmt, clippy, and
# the offline backend-e2e smoke on synthesized artifacts).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo test --doc"
cargo test --doc -q

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -- -D warnings"
cargo clippy -- -D warnings

echo "==> backend-e2e smoke (native CPU backend, synthesized artifacts)"
rm -rf target/ci-artifacts-synth
cargo run --release --bin hc-smoe -- synth --out target/ci-artifacts-synth
HCSMOE_ARTIFACTS=target/ci-artifacts-synth \
  cargo run --release --example e2e_compress_eval

echo "==> generation smoke (KV-cached decode + continuous-batching server)"
HCSMOE_ARTIFACTS=target/ci-artifacts-synth \
  cargo run --release --example generate_merged

echo "ci_check: all green"
