#!/usr/bin/env bash
# Assert the decode_batch_sweep contract on a full-run BENCH_generate.json:
# the section must exist, and batched decode at B=4 must at least match the
# per-sequence run_decode loop (speedup >= 1.0 — a noise-tolerant floor; on
# a multi-core runner the measured speedup is expected well above 1, and
# the JSON row records the actual value). CI runs this in the backend-e2e
# job after `HCSMOE_BENCH_ONLY=generate cargo bench --bench
# perf_microbench`; contributors can run it locally the same way.
#
# With no argument the script probes both candidate locations: cargo runs
# bench binaries with the PACKAGE root (rust/) as working directory, so
# that is where the JSON lands when invoked via `cargo bench` from the
# workspace root.
#
# The parse relies on bench_support::write_generate_json's stable
# formatting: one JSON object per line, "batch" keys only in the
# decode_batch_sweep section.
set -euo pipefail

f="${1:-}"
if [ -z "$f" ]; then
  for cand in rust/BENCH_generate.json BENCH_generate.json; do
    [ -f "$cand" ] && { f="$cand"; break; }
  done
fi
[ -n "$f" ] && [ -f "$f" ] || { echo "check_decode_batch: BENCH_generate.json not found (looked in rust/ and .)"; exit 1; }

grep -q '"decode_batch_sweep"' "$f" \
  || { echo "check_decode_batch: $f has no decode_batch_sweep section"; exit 1; }

line=$(grep '"batch": 4,' "$f" | head -n 1)
[ -n "$line" ] || { echo "check_decode_batch: no B=4 row in decode_batch_sweep"; exit 1; }

speedup=$(echo "$line" | sed -n 's/.*"speedup": \([0-9][0-9.]*\).*/\1/p')
[ -n "$speedup" ] || { echo "check_decode_batch: no speedup field in: $line"; exit 1; }

awk -v s="$speedup" 'BEGIN { exit (s >= 1.0) ? 0 : 1 }' || {
  echo "check_decode_batch: batched B=4 decode is SLOWER than the per-sequence loop (speedup = ${speedup}x) in $f"
  exit 1
}
echo "check_decode_batch: OK — B=4 batched/sequential speedup = ${speedup}x ($f)"
