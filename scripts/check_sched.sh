#!/usr/bin/env bash
# Gate the scheduler contracts in CI (backend-e2e job):
#
#  1. `cargo test --test scheduler` — chunked-prefill logits bit-identical
#     to whole-prompt across layouts, interactive-over-batch priority
#     ordering, the preemption storm (resumed streams bit-identical, zero
#     leaked blocks), the chunked-prefill stall bound, queued-request
#     drain on shutdown, and deadline-miss accounting.
#  2. BENCH_generate.json must contain the `sched_sweep` section with
#     both a "chunked" and an "unchunked" row, and the chunked p99
#     inter-token latency must not exceed the unchunked one — chunking
#     exists to bound decode stalls, so it must not regress tail ITL.
#
# With no argument the JSON is probed in rust/ then . (cargo runs bench
# binaries with the package root as working directory).
set -euo pipefail

cd "$(dirname "$0")/.."

echo "==> scheduler test suite (chunk bit-identity, priority, preemption, drain)"
cargo test --release --test scheduler -q

f="${1:-}"
if [ -z "$f" ]; then
  for cand in rust/BENCH_generate.json BENCH_generate.json; do
    [ -f "$cand" ] && { f="$cand"; break; }
  done
fi
[ -n "$f" ] && [ -f "$f" ] || { echo "check_sched: BENCH_generate.json not found (looked in rust/ and .)"; exit 1; }

grep -q '"sched_sweep"' "$f" \
  || { echo "check_sched: $f has no sched_sweep section"; exit 1; }

p99_of() {
  grep "\"mode\": \"$1\"" "$f" | head -n 1 \
    | sed -n 's/.*"p99_ms": \([0-9.]*\).*/\1/p'
}
chunked=$(p99_of chunked)
unchunked=$(p99_of unchunked)
[ -n "$chunked" ] || { echo "check_sched: sched_sweep has no chunked row with p99_ms"; exit 1; }
[ -n "$unchunked" ] || { echo "check_sched: sched_sweep has no unchunked row with p99_ms"; exit 1; }

awk -v c="$chunked" -v u="$unchunked" 'BEGIN { exit !(c <= u) }' \
  || { echo "check_sched: chunked p99 ITL ${chunked}ms exceeds unchunked ${unchunked}ms — the stall-bound benefit regressed"; exit 1; }
echo "check_sched: OK — chunked p99 ITL ${chunked}ms <= unchunked ${unchunked}ms ($f)"
