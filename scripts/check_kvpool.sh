#!/usr/bin/env bash
# Gate the paged KV-cache pool contracts in CI (backend-e2e job):
#
#  1. `cargo test --test kvpool` — paged==flat bit-identity across layouts
#     and thread counts, prefix-sharing/copy-on-write correctness,
#     blocked-then-admitted admission ordering, the budgeted long-context
#     burst, and the no-block-leak assertion after a mixed workload.
#  2. BENCH_generate.json must contain the `kv_cache_sweep` section and
#     every row must report `"reallocs": 0` — steady-state decode neither
#     regrows the flat cache's buffers (the prefill now reserves headroom)
#     nor copies rows on paged block allocation.
#
# With no argument the JSON is probed in rust/ then . (cargo runs bench
# binaries with the package root as working directory).
set -euo pipefail

cd "$(dirname "$0")/.."

echo "==> kvpool test suite (bit-identity, sharing, admission, no-leak)"
cargo test --release --test kvpool -q

f="${1:-}"
if [ -z "$f" ]; then
  for cand in rust/BENCH_generate.json BENCH_generate.json; do
    [ -f "$cand" ] && { f="$cand"; break; }
  done
fi
[ -n "$f" ] && [ -f "$f" ] || { echo "check_kvpool: BENCH_generate.json not found (looked in rust/ and .)"; exit 1; }

grep -q '"kv_cache_sweep"' "$f" \
  || { echo "check_kvpool: $f has no kv_cache_sweep section"; exit 1; }

rows=$(grep -c '"reallocs":' "$f" || true)
[ "$rows" -ge 2 ] || { echo "check_kvpool: kv_cache_sweep has $rows rows, expected >= 2 (flat + paged)"; exit 1; }

bad=$(grep '"reallocs":' "$f" | grep -v '"reallocs": 0}' || true)
if [ -n "$bad" ]; then
  echo "check_kvpool: steady-state decode reallocated — the no-realloc contract regressed:"
  echo "$bad"
  exit 1
fi
echo "check_kvpool: OK — $rows kv_cache_sweep rows, all reallocs = 0 ($f)"
