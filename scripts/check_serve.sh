#!/usr/bin/env bash
# Gate the replica scale-out contracts in CI (backend-e2e job):
#
#  1. `cargo test --test dispatch` — expert-parallel sharding bit-identical
#     to the serial path at every shard count, dispatcher-served and
#     streamed generations bit-identical to offline generate(), prefix-
#     affine placement with lease release, fleet metric merging, the HTTP
#     front end's chunked streaming / 503 backpressure / graceful drain.
#  2. BENCH_serve.json must contain a 1-replica and a 2-replica row,
#     every row must have dropped == 0 (scale-out never loses a stream),
#     and 2-replica goodput must be >= 1-replica goodput — adding a
#     replica must actually scale the fleet.
#
# With no argument the JSON is probed in rust/ then . (cargo runs bench
# binaries with the package root as working directory).
set -euo pipefail

cd "$(dirname "$0")/.."

echo "==> dispatch test suite (sharding bit-identity, dispatcher, HTTP front end)"
cargo test --release --test dispatch -q

f="${1:-}"
if [ -z "$f" ]; then
  for cand in rust/BENCH_serve.json BENCH_serve.json; do
    [ -f "$cand" ] && { f="$cand"; break; }
  done
fi
[ -n "$f" ] && [ -f "$f" ] || { echo "check_serve: BENCH_serve.json not found (looked in rust/ and .)"; exit 1; }

field_of() { # field_of <replicas> <field>
  grep "\"replicas\": $1," "$f" | head -n 1 \
    | sed -n "s/.*\"$2\": \([0-9.]*\).*/\1/p"
}

for n in 1 2; do
  dropped=$(field_of "$n" dropped)
  [ -n "$dropped" ] || { echo "check_serve: $f has no ${n}-replica row"; exit 1; }
  [ "$dropped" = "0" ] \
    || { echo "check_serve: ${n}-replica row dropped ${dropped} streams — scale-out must never lose a stream"; exit 1; }
done

g1=$(field_of 1 goodput)
g2=$(field_of 2 goodput)
[ -n "$g1" ] && [ -n "$g2" ] || { echo "check_serve: rows missing goodput column"; exit 1; }

awk -v a="$g2" -v b="$g1" 'BEGIN { exit !(a >= b) }' \
  || { echo "check_serve: 2-replica goodput ${g2} req/s below 1-replica ${g1} req/s — adding a replica must not shrink throughput"; exit 1; }
echo "check_serve: OK — zero dropped streams; 2-replica goodput ${g2} >= 1-replica ${g1} req/s ($f)"
