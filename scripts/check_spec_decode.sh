#!/usr/bin/env bash
# Gate the speculative-decoding contracts in CI (backend-e2e job):
#
#  1. `cargo test --test spec_decode` — speculative output bit-identical
#     to the plain decode loop across layouts (full / masked / compact /
#     shared-expert), flat + paged caches, k in {1,2,4,8}, greedy and
#     seeded sampling; multi-position verify vs sequential decodes at 1/2/4
#     threads; rollback restores a byte-fresh prefix; the serving
#     interleave, intake-validation, preemption-leak and priority tests.
#  2. BENCH_generate.json must contain the `spec_decode_sweep` section,
#     every row must report `"exact": true` (speculation may never change
#     the token stream), and at least one k >= 2 row must have accepted
#     drafts (acceptance_rate > 0) — a drafter that never lands a token
#     means the compact variant diverged from the verifier entirely.
#
# With no argument the JSON is probed in rust/ then . (cargo runs bench
# binaries with the package root as working directory).
set -euo pipefail

cd "$(dirname "$0")/.."

echo "==> speculative decoding test suite (exact-output pinning, rollback, serving)"
cargo test --release --test spec_decode -q

f="${1:-}"
if [ -z "$f" ]; then
  for cand in rust/BENCH_generate.json BENCH_generate.json; do
    [ -f "$cand" ] && { f="$cand"; break; }
  done
fi
[ -n "$f" ] && [ -f "$f" ] || { echo "check_spec_decode: BENCH_generate.json not found (looked in rust/ and .)"; exit 1; }

grep -q '"spec_decode_sweep"' "$f" \
  || { echo "check_spec_decode: $f has no spec_decode_sweep section"; exit 1; }

rows=$(grep -c '"draft_k":' "$f" || true)
[ "$rows" -ge 1 ] || { echo "check_spec_decode: spec_decode_sweep has no rows"; exit 1; }

if grep '"draft_k":' "$f" | grep -q '"exact": false'; then
  echo "check_spec_decode: a spec_decode_sweep row reports exact=false — speculative output diverged from plain decode"
  exit 1
fi

# at least one k >= 2 row must land drafts: acceptance_rate strictly > 0
accepted_any=$(grep '"draft_k":' "$f" \
  | grep -v '"draft_k": 1,' \
  | sed -n 's/.*"acceptance_rate": \([0-9.]*\).*/\1/p' \
  | awk 'BEGIN { any = 0 } { if ($1 > 0) any = 1 } END { print any }')
[ "$accepted_any" = "1" ] \
  || { echo "check_spec_decode: no k >= 2 row accepted any drafts — compact drafter never agrees with the verifier"; exit 1; }

echo "check_spec_decode: OK — all rows exact, drafter lands tokens ($f)"
